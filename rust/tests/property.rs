//! Property-based tests (in-house harness, see `flip::util::proptest`):
//! randomized graphs and configurations against system invariants.

use flip::compiler::{compile, CompileOpts};
use flip::config::ArchConfig;
use flip::graph::{reference, Delta, Graph};
use flip::prop_assert;
use flip::sim::flip::{self as flipsim, SimOptions};
use flip::util::{proptest::check, Rng};
use flip::workloads::program::VertexProgram;
use flip::workloads::{mis, navigation, pagerank, view_for, Workload};

/// Random connected-ish weighted graph with n in [lo, hi].
fn random_graph(rng: &mut Rng, lo: usize, hi: usize, directed: bool) -> Graph {
    let n = rng.range(lo, hi + 1);
    let m = n + rng.range(0, 2 * n);
    let mut edges = Vec::with_capacity(n - 1 + m);
    // random spanning tree for (weak) connectivity
    for v in 1..n as u32 {
        let p = rng.below(v as u64) as u32;
        edges.push((p, v, 1 + rng.below(9) as u32));
    }
    for _ in 0..m {
        let u = rng.below(n as u64) as u32;
        let v = rng.below(n as u64) as u32;
        if u != v {
            edges.push((u, v, 1 + rng.below(9) as u32));
        }
    }
    Graph::from_edges(n, &edges, directed)
}

fn random_workload(rng: &mut Rng) -> Workload {
    Workload::ALL[rng.below(3) as usize]
}

#[test]
fn prop_sim_matches_reference_on_random_graphs() {
    check("sim_matches_reference", 40, |rng| {
        let directed = rng.chance(0.5);
        let g = random_graph(rng, 8, 80, directed);
        let w = random_workload(rng);
        let view = view_for(w, &g);
        let cfg = ArchConfig::default();
        let c = compile(&view, &cfg, &CompileOpts { seed: rng.next_u64(), ..Default::default() });
        let src = rng.below(g.num_vertices() as u64) as u32;
        let r = flipsim::run(&c, w, src, &SimOptions::default())
            .map_err(|e| format!("sim error: {e}"))?;
        let want = w.reference(&view, src);
        prop_assert!(r.attrs == want, "{} mismatch on |V|={}", w.name(), g.num_vertices());
        Ok(())
    });
}

#[test]
fn prop_event_core_equals_naive_stepper() {
    // The tentpole invariant of the event-driven scheduler (active-set
    // sweep + idle-cycle fast-forward + ring arenas): cycle-for-cycle
    // equivalence with the retained naive reference stepper — identical
    // cycles, attrs, edges_traversed and every SimMetrics counter
    // (including the activity counts the energy model consumes).
    check("event_core_equals_naive", 30, |rng| {
        let directed = rng.chance(0.5);
        let g = random_graph(rng, 8, 96, directed);
        let w = random_workload(rng);
        let view = view_for(w, &g);
        let cfg = ArchConfig::default();
        let c = compile(&view, &cfg, &CompileOpts { seed: rng.next_u64(), ..Default::default() });
        let src = rng.below(g.num_vertices() as u64) as u32;
        let opts = SimOptions { trace_parallelism: rng.chance(0.3), ..Default::default() };
        let fast = flipsim::run(&c, w, src, &opts).map_err(|e| format!("event core: {e}"))?;
        let naive = flip::sim::naive::run(&c, w, src, &opts)
            .map_err(|e| format!("naive core: {e}"))?;
        prop_assert!(fast.cycles == naive.cycles, "cycles {} != {}", fast.cycles, naive.cycles);
        prop_assert!(fast.attrs == naive.attrs, "attrs diverge ({})", w.name());
        prop_assert!(
            fast.edges_traversed == naive.edges_traversed,
            "edges {} != {}",
            fast.edges_traversed,
            naive.edges_traversed
        );
        prop_assert!(
            fast.sim == naive.sim,
            "metrics diverge ({}): fast {:?} naive {:?}",
            w.name(),
            fast.sim,
            naive.sim
        );
        Ok(())
    });
}

#[test]
fn prop_event_core_equals_naive_with_swapping() {
    // same invariant across the swap engine / SPM parking path: graphs
    // larger than one array copy, where the fast-forward saves the most
    check("event_core_equals_naive_swapping", 6, |rng| {
        let g = random_graph(rng, 260, 400, false);
        let cfg = ArchConfig::default();
        let c = compile(&g, &cfg, &CompileOpts { seed: rng.next_u64(), ..Default::default() });
        prop_assert!(c.placement.num_copies >= 2, "expected replication");
        let opts =
            SimOptions { max_cycles: 1_000_000_000, watchdog: 5_000_000, ..Default::default() };
        let fast = flipsim::run(&c, Workload::Bfs, 0, &opts).map_err(|e| e.to_string())?;
        let naive =
            flip::sim::naive::run(&c, Workload::Bfs, 0, &opts).map_err(|e| e.to_string())?;
        prop_assert!(fast.cycles == naive.cycles, "cycles {} != {}", fast.cycles, naive.cycles);
        prop_assert!(fast.attrs == naive.attrs, "attrs diverge under swapping");
        prop_assert!(fast.sim == naive.sim, "metrics diverge under swapping");
        Ok(())
    });
}

/// Run one program through the monomorphized path (`P` concrete), the
/// retained dyn-shim (`P = dyn VertexProgram` — the same generic function
/// instantiated at the trait object) and the dyn-dispatched naive oracle.
/// All three must agree bitwise on cycles, attrs, edges, and every
/// SimMetrics counter — the PR-5 monomorphization invariant.
fn assert_mono_dyn_naive<P: VertexProgram>(
    c: &flip::compiler::CompiledGraph,
    vp: &P,
    src: u32,
    opts: &SimOptions,
) -> Result<(), String> {
    let mono = flipsim::run_program(c, vp, src, opts).map_err(|e| format!("mono: {e}"))?;
    let shim = flipsim::run_program(c, vp as &dyn VertexProgram, src, opts)
        .map_err(|e| format!("dyn shim: {e}"))?;
    let naive =
        flip::sim::naive::run_program(c, vp, src, opts).map_err(|e| format!("naive: {e}"))?;
    for (path, r) in [("dyn shim", &shim), ("naive oracle", &naive)] {
        if mono.cycles != r.cycles {
            return Err(format!(
                "{}: {path} cycles {} != mono {}",
                vp.name(),
                r.cycles,
                mono.cycles
            ));
        }
        if mono.attrs != r.attrs {
            return Err(format!("{}: {path} attrs diverge from mono", vp.name()));
        }
        if mono.edges_traversed != r.edges_traversed {
            return Err(format!("{}: {path} edge counts diverge from mono", vp.name()));
        }
        if mono.sim != r.sim {
            return Err(format!("{}: {path} metrics diverge from mono", vp.name()));
        }
    }
    Ok(())
}

#[test]
fn prop_mono_path_equals_dyn_shim_and_naive() {
    // all six workloads: monomorphized run ≡ dyn-shim run ≡ naive oracle,
    // bitwise (cycles, attrs, SimMetrics)
    check("mono_equals_dyn_and_naive", 18, |rng| {
        let g = random_graph(rng, 8, 80, false);
        let cfg = ArchConfig::default();
        let seed = rng.next_u64();
        let opts = SimOptions::default();
        let n = g.num_vertices() as u64;
        match rng.below(6) {
            w @ 0..=2 => {
                let wl = Workload::ALL[w as usize];
                let view = view_for(wl, &g);
                let c = compile(&view, &cfg, &CompileOpts { seed, ..Default::default() });
                let src = rng.below(n) as u32;
                flip::workloads::with_builtin(wl, |p| assert_mono_dyn_naive(&c, p, src, &opts))?;
            }
            3 => {
                let contribs =
                    reference::pagerank_contribs(&g, &reference::pagerank_init(g.num_vertices()));
                let vp = pagerank::PageRankRound { contribs };
                let c = compile(&g, &cfg, &CompileOpts { seed, ..Default::default() });
                assert_mono_dyn_naive(&c, &vp, 0, &opts)?;
            }
            4 => {
                let (s, t) = (rng.below(n) as u32, rng.below(n) as u32);
                let vp = navigation::AStar::new(&g, s, t, 3);
                let c = compile(&g, &cfg, &CompileOpts { seed, ..Default::default() });
                assert_mono_dyn_naive(&c, &vp, s, &opts)?;
            }
            _ => {
                let (m, view) = mis::Mis::build(&g, rng.next_u64());
                let c = compile(&view, &cfg, &CompileOpts { seed, ..Default::default() });
                assert_mono_dyn_naive(&c, &m, 0, &opts)?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_mono_path_equals_dyn_shim_with_swapping() {
    // the same three-way invariant across the swap engine / SPM parking
    // path (multi-copy graphs)
    check("mono_equals_dyn_swapping", 3, |rng| {
        let g = random_graph(rng, 260, 380, false);
        let cfg = ArchConfig::default();
        let c = compile(&g, &cfg, &CompileOpts { seed: rng.next_u64(), ..Default::default() });
        prop_assert!(c.placement.num_copies >= 2, "expected replication");
        let opts =
            SimOptions { max_cycles: 1_000_000_000, watchdog: 5_000_000, ..Default::default() };
        let src = rng.below(g.num_vertices() as u64) as u32;
        flip::workloads::with_builtin(Workload::Bfs, |p| {
            assert_mono_dyn_naive(&c, p, src, &opts)
        })?;
        Ok(())
    });
}

/// Build one of the three extended vertex programs plus the graph view it
/// compiles against. Returns (program, view, source).
fn random_extended_program(
    rng: &mut Rng,
    g: &Graph,
) -> (Box<dyn VertexProgram>, Graph, u32) {
    match rng.below(3) {
        0 => {
            // one realistic PageRank round (contributions of the uniform
            // initial ranks)
            let contribs =
                reference::pagerank_contribs(g, &reference::pagerank_init(g.num_vertices()));
            (Box::new(pagerank::PageRankRound { contribs }), g.clone(), 0)
        }
        1 => {
            let s = rng.below(g.num_vertices() as u64) as u32;
            let t = rng.below(g.num_vertices() as u64) as u32;
            (Box::new(navigation::AStar::new(g, s, t, 3)), g.clone(), s)
        }
        _ => {
            let (m, view) = mis::Mis::build(g, rng.next_u64());
            (Box::new(m), view, 0)
        }
    }
}

#[test]
fn prop_extended_programs_match_their_oracles() {
    // the determinism contract of DESIGN.md §5: the asynchronous fabric
    // reproduces each extended program's CPU oracle exactly
    check("extended_matches_oracle", 30, |rng| {
        let g = random_graph(rng, 8, 80, false);
        let (vp, view, src) = random_extended_program(rng, &g);
        let cfg = ArchConfig::default();
        let c = compile(&view, &cfg, &CompileOpts { seed: rng.next_u64(), ..Default::default() });
        let r = flipsim::run_program(&c, vp.as_ref(), src, &SimOptions::default())
            .map_err(|e| format!("{}: {e}", vp.name()))?;
        let want = vp.reference(&view, src);
        prop_assert!(r.attrs == want, "{} oracle mismatch on |V|={}", vp.name(), g.num_vertices());
        Ok(())
    });
}

#[test]
fn prop_event_core_equals_naive_extended() {
    // scheduler equivalence (cycles, attrs, every metric) extends to the
    // three new workloads on the vertex-program layer
    check("event_equals_naive_extended", 24, |rng| {
        let g = random_graph(rng, 8, 96, false);
        let (vp, view, src) = random_extended_program(rng, &g);
        let cfg = ArchConfig::default();
        let c = compile(&view, &cfg, &CompileOpts { seed: rng.next_u64(), ..Default::default() });
        let opts = SimOptions { trace_parallelism: rng.chance(0.3), ..Default::default() };
        let fast = flipsim::run_program(&c, vp.as_ref(), src, &opts)
            .map_err(|e| format!("event core ({}): {e}", vp.name()))?;
        let naive = flip::sim::naive::run_program(&c, vp.as_ref(), src, &opts)
            .map_err(|e| format!("naive core ({}): {e}", vp.name()))?;
        prop_assert!(
            fast.cycles == naive.cycles,
            "{}: cycles {} != {}",
            vp.name(),
            fast.cycles,
            naive.cycles
        );
        prop_assert!(fast.attrs == naive.attrs, "{}: attrs diverge", vp.name());
        prop_assert!(
            fast.edges_traversed == naive.edges_traversed,
            "{}: edges {} != {}",
            vp.name(),
            fast.edges_traversed,
            naive.edges_traversed
        );
        prop_assert!(fast.sim == naive.sim, "{}: metrics diverge", vp.name());
        Ok(())
    });
}

#[test]
fn prop_event_core_equals_naive_extended_with_swapping() {
    // same invariant across the swap engine / SPM parking path: the dense
    // seeding of PageRank/MIS stresses the pending-seed release, A* the
    // single-source parked path
    check("event_equals_naive_extended_swapping", 4, |rng| {
        let g = random_graph(rng, 260, 380, false);
        let (vp, view, src) = random_extended_program(rng, &g);
        let cfg = ArchConfig::default();
        let c = compile(&view, &cfg, &CompileOpts { seed: rng.next_u64(), ..Default::default() });
        prop_assert!(c.placement.num_copies >= 2, "expected replication");
        let opts =
            SimOptions { max_cycles: 1_000_000_000, watchdog: 5_000_000, ..Default::default() };
        let fast = flipsim::run_program(&c, vp.as_ref(), src, &opts)
            .map_err(|e| format!("event core ({}): {e}", vp.name()))?;
        let naive = flip::sim::naive::run_program(&c, vp.as_ref(), src, &opts)
            .map_err(|e| format!("naive core ({}): {e}", vp.name()))?;
        prop_assert!(
            fast.cycles == naive.cycles,
            "{}: cycles {} != {}",
            vp.name(),
            fast.cycles,
            naive.cycles
        );
        prop_assert!(fast.attrs == naive.attrs, "{}: attrs diverge under swapping", vp.name());
        prop_assert!(fast.sim == naive.sim, "{}: metrics diverge under swapping", vp.name());
        prop_assert!(
            fast.attrs == vp.reference(&view, src),
            "{}: oracle mismatch under swapping",
            vp.name()
        );
        Ok(())
    });
}

#[test]
fn prop_instance_reuse_equals_fresh() {
    // the SimInstance reset() contract (DESIGN.md §6): one reused machine
    // serving a mixed query stream — across workloads AND across the
    // directed/undirected compiled views — is bit-identical to a fresh
    // cold-start machine per query
    check("instance_reuse_equals_fresh", 14, |rng| {
        let directed = rng.chance(0.5);
        let g = random_graph(rng, 8, 96, directed);
        let cfg = ArchConfig::default();
        let pair = flip::experiments::harness::CompiledPair::build(&g, &cfg, rng.next_u64());
        let mut inst = flip::sim::SimInstance::new(&pair.directed);
        for _ in 0..4 {
            let w = random_workload(rng);
            let c = pair.for_workload(w);
            let src = rng.below(g.num_vertices() as u64) as u32;
            let opts = SimOptions { trace_parallelism: rng.chance(0.3), ..Default::default() };
            let reused =
                inst.run(c, w, src, &opts).map_err(|e| format!("reused ({}): {e}", w.name()))?;
            let fresh =
                flipsim::run(c, w, src, &opts).map_err(|e| format!("fresh ({}): {e}", w.name()))?;
            prop_assert!(
                reused.cycles == fresh.cycles,
                "{} src {src}: cycles {} != {}",
                w.name(),
                reused.cycles,
                fresh.cycles
            );
            prop_assert!(reused.attrs == fresh.attrs, "{} src {src}: attrs diverge", w.name());
            prop_assert!(
                reused.edges_traversed == fresh.edges_traversed,
                "{} src {src}: edges diverge",
                w.name()
            );
            prop_assert!(reused.sim == fresh.sim, "{} src {src}: metrics diverge", w.name());
        }
        Ok(())
    });
}

#[test]
fn prop_instance_reuse_equals_fresh_extended() {
    // the same reuse contract under the extended vertex programs (dense
    // seeding, aux/bound registers, coalescing disabled for MIS)
    check("instance_reuse_equals_fresh_extended", 10, |rng| {
        let g = random_graph(rng, 8, 80, false);
        let cfg = ArchConfig::default();
        let mut inst: Option<flip::sim::SimInstance> = None;
        for _ in 0..2 {
            let (vp, view, src) = random_extended_program(rng, &g);
            let c =
                compile(&view, &cfg, &CompileOpts { seed: rng.next_u64(), ..Default::default() });
            let inst = inst.get_or_insert_with(|| flip::sim::SimInstance::new(&c));
            let reused = inst
                .run_program(&c, vp.as_ref(), src, &SimOptions::default())
                .map_err(|e| format!("reused ({}): {e}", vp.name()))?;
            let fresh = flipsim::run_program(&c, vp.as_ref(), src, &SimOptions::default())
                .map_err(|e| format!("fresh ({}): {e}", vp.name()))?;
            prop_assert!(reused.cycles == fresh.cycles, "{}: cycles diverge", vp.name());
            prop_assert!(reused.attrs == fresh.attrs, "{}: attrs diverge", vp.name());
            prop_assert!(reused.sim == fresh.sim, "{}: metrics diverge", vp.name());
        }
        Ok(())
    });
}

#[test]
fn prop_instance_reuse_equals_fresh_with_swapping() {
    // reuse across the swap engine / SPM parking path: the machine ends a
    // run with the dirtiest state (stale residents, drained SPM lists)
    check("instance_reuse_equals_fresh_swapping", 4, |rng| {
        let g = random_graph(rng, 260, 380, false);
        let cfg = ArchConfig::default();
        let c = compile(&g, &cfg, &CompileOpts { seed: rng.next_u64(), ..Default::default() });
        prop_assert!(c.placement.num_copies >= 2, "expected replication");
        let opts =
            SimOptions { max_cycles: 1_000_000_000, watchdog: 5_000_000, ..Default::default() };
        let mut inst = flip::sim::SimInstance::new(&c);
        for _ in 0..2 {
            let src = rng.below(g.num_vertices() as u64) as u32;
            let reused = inst.run(&c, Workload::Bfs, src, &opts).map_err(|e| e.to_string())?;
            let fresh = flipsim::run(&c, Workload::Bfs, src, &opts).map_err(|e| e.to_string())?;
            prop_assert!(reused.cycles == fresh.cycles, "src {src}: cycles diverge");
            prop_assert!(reused.attrs == fresh.attrs, "src {src}: attrs diverge");
            prop_assert!(reused.sim == fresh.sim, "src {src}: metrics diverge");
        }
        Ok(())
    });
}

#[test]
fn prop_attr_updates_equal_full_recompile() {
    // the traffic-update invariant (DESIGN.md §6): placement depends only
    // on topology, so a weight-only delta patched into the live tables is
    // indistinguishable — placement, attrs, cycles, every metric — from a
    // full recompile of the reweighted graph
    check("attr_updates_equal_recompile", 10, |rng| {
        let directed = rng.chance(0.5);
        let g = random_graph(rng, 8, 120, directed);
        let cfg = ArchConfig::default();
        let seed = rng.next_u64();
        let c0 = compile(&g, &cfg, &CompileOpts { seed, ..Default::default() });
        let mut changes = Vec::new();
        for (u, v, _) in g.arcs() {
            if (directed || u < v) && rng.chance(0.4) {
                changes.push((u, v, 1 + rng.below(19) as u32));
            }
        }
        let delta = Delta::from_edges(&g, &changes);
        let mut g2 = g.clone();
        g2.apply_delta(&delta)?;
        let mut patched = c0.clone();
        patched.apply_attr_updates(&delta)?;
        let full = compile(&g2, &cfg, &CompileOpts { seed, ..Default::default() });
        prop_assert!(
            patched.placement.slots == full.placement.slots,
            "placement moved on a weight-only recompile"
        );
        let src = rng.below(g.num_vertices() as u64) as u32;
        let a = flipsim::run(&patched, Workload::Sssp, src, &SimOptions::default())
            .map_err(|e| e.to_string())?;
        let b = flipsim::run(&full, Workload::Sssp, src, &SimOptions::default())
            .map_err(|e| e.to_string())?;
        prop_assert!(a.cycles == b.cycles, "cycles {} != {}", a.cycles, b.cycles);
        prop_assert!(a.attrs == b.attrs, "attrs diverge");
        prop_assert!(a.sim == b.sim, "metrics diverge");
        prop_assert!(a.attrs == reference::dijkstra(&g2, src), "oracle mismatch on new weights");
        Ok(())
    });
}

#[test]
fn prop_placement_structurally_valid() {
    check("placement_valid", 40, |rng| {
        let directed = rng.chance(0.5);
        let g = random_graph(rng, 4, 300, directed);
        let cfg = ArchConfig::default();
        let c = compile(&g, &cfg, &CompileOpts { seed: rng.next_u64(), ..Default::default() });
        c.placement.validate(&g, &cfg)?;
        // every arc is covered by an inter entry to its destination
        // (PE, slice) — entries are deduplicated per destination, since a
        // packet delivers to every matching intra entry — and has its own
        // matching intra entry
        for (u, v, wt) in g.arcs() {
            let su = c.placement.slots[u as usize];
            let sv = c.placement.slots[v as usize];
            let (dx, dy) = su.pe.offset_to(sv.pe);
            let slice = c.placement.slice_of(&cfg, v);
            prop_assert!(
                c.inter_list(su.copy, su.pe.index(&cfg), su.reg)
                    .iter()
                    .any(|e| (e.dx, e.dy, e.slice) == (dx, dy, slice)),
                "missing inter entry {u}->{v}"
            );
            let (m, _) = c.intra_lookup(sv.copy, sv.pe.index(&cfg), u);
            prop_assert!(
                m.iter().any(|x| x.dst_reg == sv.reg && x.weight == wt),
                "missing intra entry {u}->{v}"
            );
        }
        Ok(())
    });
}

#[test]
fn prop_inter_lists_farthest_first() {
    check("farthest_first", 25, |rng| {
        let g = random_graph(rng, 8, 128, false);
        let cfg = ArchConfig::default();
        let c = compile(&g, &cfg, &CompileOpts { seed: rng.next_u64(), ..Default::default() });
        for copy in 0..c.placement.num_copies as u16 {
            for pe in 0..cfg.num_pes() {
                for reg in 0..cfg.drf_size {
                    let list = c.inter_list(copy, pe, reg as u8);
                    for w in list.windows(2) {
                        prop_assert!(w[0].hops() >= w[1].hops(), "layout not farthest-first");
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_yx_route_always_reaches_destination() {
    check("yx_reaches", 200, |rng| {
        let cfg = ArchConfig::default();
        let from = flip::arch::PeCoord {
            x: rng.below(cfg.array_w as u64) as u8,
            y: rng.below(cfg.array_h as u64) as u8,
        };
        let to = flip::arch::PeCoord {
            x: rng.below(cfg.array_w as u64) as u8,
            y: rng.below(cfg.array_h as u64) as u8,
        };
        let (dx, dy) = from.offset_to(to);
        let mut p = flip::arch::Packet { src_vid: 0, attr: 0, dx, dy, slice: 0 };
        let mut cur = from;
        let mut hops = 0;
        while let Some(dir) = flip::arch::yx_route(p.dx, p.dy) {
            hops += 1;
            prop_assert!(hops <= 32, "route too long");
            // move the coordinate along dir and hop the packet
            cur = cur
                .neighbors(&cfg)
                .find(|&(d, _)| d == dir)
                .map(|(_, c)| c)
                .ok_or_else(|| format!("walked off the mesh at {cur:?} dir {dir:?}"))?;
            p = p.hop(dir);
        }
        prop_assert!(cur == to, "YX ended at {cur:?}, wanted {to:?}");
        prop_assert!(hops == from.hops(to), "YX took a detour");
        Ok(())
    });
}

#[test]
fn prop_attrs_monotonically_improve() {
    // Final attributes never exceed initial ones (min-plus relaxation is
    // monotone) and sources end at 0.
    check("monotone", 25, |rng| {
        let g = random_graph(rng, 8, 64, false);
        let cfg = ArchConfig::default();
        let c = compile(&g, &cfg, &CompileOpts { seed: rng.next_u64(), ..Default::default() });
        let src = rng.below(g.num_vertices() as u64) as u32;
        let r = flipsim::run(&c, Workload::Sssp, src, &SimOptions::default())
            .map_err(|e| e.to_string())?;
        prop_assert!(r.attrs[src as usize] == 0, "source distance not 0");
        for (v, &a) in r.attrs.iter().enumerate() {
            if a != flip::graph::INF {
                prop_assert!(a < flip::graph::INF, "vertex {v} overflowed");
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sim_deterministic() {
    check("deterministic", 15, |rng| {
        let g = random_graph(rng, 8, 64, false);
        let cfg = ArchConfig::default();
        let c = compile(&g, &cfg, &CompileOpts { seed: rng.next_u64(), ..Default::default() });
        let a = flipsim::run(&c, Workload::Bfs, 0, &SimOptions::default())
            .map_err(|e| e.to_string())?;
        let b = flipsim::run(&c, Workload::Bfs, 0, &SimOptions::default())
            .map_err(|e| e.to_string())?;
        prop_assert!(a.cycles == b.cycles, "cycles differ");
        prop_assert!(a.attrs == b.attrs, "attrs differ");
        prop_assert!(
            a.sim.packets_delivered == b.sim.packets_delivered,
            "packet counts differ"
        );
        Ok(())
    });
}

#[test]
fn prop_multicopy_graphs_swap_and_stay_exact() {
    check("multicopy", 8, |rng| {
        let g = random_graph(rng, 260, 420, false);
        let cfg = ArchConfig::default();
        let c = compile(&g, &cfg, &CompileOpts { seed: rng.next_u64(), ..Default::default() });
        prop_assert!(c.placement.num_copies >= 2, "expected replication");
        let opts =
            SimOptions { max_cycles: 1_000_000_000, watchdog: 5_000_000, ..Default::default() };
        let r = flipsim::run(&c, Workload::Bfs, 0, &opts).map_err(|e| e.to_string())?;
        prop_assert!(
            r.attrs == reference::bfs_levels(&g, 0),
            "BFS mismatch with swapping (|V|={})",
            g.num_vertices()
        );
        Ok(())
    });
}

#[test]
fn prop_tiny_buffers_still_correct() {
    // failure injection: shrink every buffer to near-minimum; the memory-
    // buffer escape path must keep the NoC deadlock-free and exact.
    check("tiny_buffers", 12, |rng| {
        let g = random_graph(rng, 8, 48, false);
        let mut cfg = ArchConfig::default();
        cfg.input_buf_cap = 1;
        cfg.aluin_cap = 1;
        cfg.aluout_cap = 1;
        let c = compile(&g, &cfg, &CompileOpts { seed: rng.next_u64(), ..Default::default() });
        let w = random_workload(rng);
        let view = view_for(w, &g);
        let c = if w.needs_undirected() && g.is_directed() {
            compile(&view, &cfg, &CompileOpts { seed: rng.next_u64(), ..Default::default() })
        } else {
            c
        };
        let r = flipsim::run(&c, w, 0, &SimOptions::default()).map_err(|e| e.to_string())?;
        prop_assert!(
            r.attrs == w.reference(&view, 0),
            "{} wrong under tiny buffers",
            w.name()
        );
        Ok(())
    });
}

#[test]
fn prop_odd_array_shapes_work() {
    // non-square and non-power-of-two arrays (cluster-divisible)
    check("odd_arrays", 10, |rng| {
        let shapes = [(2usize, 4usize), (4, 2), (6, 4), (4, 6), (10, 6)];
        let (w, h) = shapes[rng.below(shapes.len() as u64) as usize];
        let cfg = ArchConfig { array_w: w, array_h: h, ..Default::default() };
        let g = random_graph(rng, 8, cfg.capacity().min(64), false);
        let c = compile(&g, &cfg, &CompileOpts { seed: rng.next_u64(), ..Default::default() });
        let r = flipsim::run(&c, Workload::Bfs, 0, &SimOptions::default())
            .map_err(|e| e.to_string())?;
        prop_assert!(
            r.attrs == reference::bfs_levels(&g, 0),
            "BFS wrong on {w}x{h} array"
        );
        Ok(())
    });
}

//! Deterministic, dependency-free fuzz suite: an in-crate xorshift
//! generator drives random graphs, `Delta` batches and `Job` mixes
//! through the system's differentials —
//!
//! * sharded (K ∈ {1, 2, 4}) vs single-chip event core vs CPU oracle,
//! * event core vs naive reference stepper (cycles, attrs, metrics),
//! * weight-delta patching vs full recompilation,
//! * engine batches vs sequential runs,
//! * ANN beam search: fused lanes vs sequential vs the CPU oracle.
//!
//! Every case derives from one 64-bit seed. On a mismatch the panic
//! names that seed; re-run just it with
//! `FLIP_FUZZ_SEED=0x<seed> cargo test -q --test fuzz` (one-line repro:
//! the env var narrows every suite to exactly that seed).

mod common;

use flip::compiler::{compile, CompileOpts};
use flip::config::ArchConfig;
use flip::graph::{generate, reference, Delta, Graph};
use flip::sim::flip as flipsim;
use flip::sim::flip::SimOptions;
use flip::sim::multichip::{self, ShardedMachine};
use flip::sim::{naive, BatchInstance};
use flip::workloads::ann::{self, AnnParams, AnnQuery};
use flip::workloads::program::VertexProgram;
use flip::workloads::Workload;

/// xorshift64* — tiny, deterministic, and independent of the crate's
/// xoshiro [`flip::util::Rng`] so fuzz inputs cannot covary with any
/// in-crate randomness.
struct XorShift {
    s: u64,
}

impl XorShift {
    fn new(seed: u64) -> XorShift {
        // avoid the all-zero fixed point
        XorShift { s: seed | 1 }
    }

    fn next_u64(&mut self) -> u64 {
        let mut x = self.s;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.s = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below((hi - lo) as u64) as usize
    }

    fn chance(&mut self, p_percent: u64) -> bool {
        self.below(100) < p_percent
    }
}

/// The per-suite seed list: `cases` seeds derived from `salt`, or just
/// the user's `FLIP_FUZZ_SEED` when set (the one-line repro path).
fn seeds(salt: u64, cases: usize) -> Vec<u64> {
    if let Ok(s) = std::env::var("FLIP_FUZZ_SEED") {
        let s = s.trim();
        let parsed = match s.strip_prefix("0x") {
            Some(h) => u64::from_str_radix(h, 16),
            None => s.parse::<u64>(),
        };
        return vec![parsed.unwrap_or_else(|_| panic!("bad FLIP_FUZZ_SEED `{s}`"))];
    }
    let mut x = XorShift::new(0xF1_1F ^ salt);
    (0..cases).map(|_| x.next_u64()).collect()
}

/// Run one fuzz case, panicking with the repro seed on failure.
fn drive(name: &str, salt: u64, cases: usize, f: impl Fn(&mut XorShift) -> Result<(), String>) {
    for seed in seeds(salt, cases) {
        let mut x = XorShift::new(seed);
        if let Err(msg) = f(&mut x) {
            panic!(
                "fuzz `{name}` failed: {msg}\n  one-line repro: \
                 FLIP_FUZZ_SEED={seed:#x} cargo test -q --test fuzz {name}"
            );
        }
    }
}

/// Random connected undirected weighted graph, |V| in [lo, hi] (shared
/// builder, drawing from this suite's xorshift stream).
fn fuzz_graph(x: &mut XorShift, lo: usize, hi: usize) -> Graph {
    common::random_graph(&mut |n| x.below(n), lo, hi)
}

/// One of the seven workload programs, with its compiled view and source.
fn fuzz_program(x: &mut XorShift, g: &Graph) -> common::ProgramCase {
    let which = x.below(7);
    common::program_case(which, g, &mut |n| x.below(n))
}

/// Random weight-only delta over existing edges (may name the same edge
/// twice — last write must win).
fn fuzz_delta(x: &mut XorShift, g: &Graph) -> Delta {
    let undirected_edges: Vec<(u32, u32)> = g
        .arcs()
        .filter(|&(u, v, _)| g.is_directed() || u < v)
        .map(|(u, v, _)| (u, v))
        .collect();
    let mut changes = Vec::new();
    for &(u, v) in &undirected_edges {
        if x.chance(35) {
            changes.push((u, v, 1 + x.below(19) as u32));
            if x.chance(20) {
                // duplicate: exercises last-wins
                changes.push((u, v, 1 + x.below(19) as u32));
            }
        }
    }
    Delta::from_edges(g, &changes)
}

#[test]
fn fuzz_sharded_vs_single_vs_oracle() {
    drive("fuzz_sharded_vs_single_vs_oracle", 0x51, 8, |x| {
        let g = fuzz_graph(x, 10, 64);
        let (vp, view, src) = fuzz_program(x, &g);
        let seed = x.next_u64();
        let cfg = ArchConfig::default();
        let c = compile(&view, &cfg, &CompileOpts { seed, ..Default::default() });
        let single = flipsim::run_program(&c, vp.as_ref(), src, &SimOptions::default())
            .map_err(|e| format!("single ({}): {e}", vp.name()))?;
        let want = vp.reference(&view, src);
        if single.attrs != want {
            return Err(format!("{}: single-chip vs oracle", vp.name()));
        }
        let k = [1usize, 2, 4][x.below(3) as usize];
        let m = ShardedMachine::build(&view, k, &cfg, seed);
        let mut insts = m.new_instances();
        let r = multichip::run_program(&m, &mut insts, vp.as_ref(), src, &SimOptions::default())
            .map_err(|e| format!("sharded K={k} ({}): {e}", vp.name()))?;
        if r.result.attrs != want {
            return Err(format!("{} K={k}: sharded vs oracle", vp.name()));
        }
        if k == 1 && (r.result.cycles != single.cycles || r.result.sim != single.sim) {
            return Err(format!("{} K=1: not metric-identical", vp.name()));
        }
        Ok(())
    });
}

#[test]
fn fuzz_event_core_vs_naive_stepper() {
    drive("fuzz_event_core_vs_naive_stepper", 0xE7, 8, |x| {
        let g = fuzz_graph(x, 10, 72);
        let (vp, view, src) = fuzz_program(x, &g);
        let seed = x.next_u64();
        let cfg = ArchConfig::default();
        let c = compile(&view, &cfg, &CompileOpts { seed, ..Default::default() });
        let opts = SimOptions { trace_parallelism: x.chance(30), ..Default::default() };
        let fast = flipsim::run_program(&c, vp.as_ref(), src, &opts)
            .map_err(|e| format!("event ({}): {e}", vp.name()))?;
        let slow = naive::run_program(&c, vp.as_ref(), src, &opts)
            .map_err(|e| format!("naive ({}): {e}", vp.name()))?;
        if fast.cycles != slow.cycles {
            return Err(format!("{}: cycles {} != {}", vp.name(), fast.cycles, slow.cycles));
        }
        if fast.attrs != slow.attrs {
            return Err(format!("{}: attrs diverge", vp.name()));
        }
        if fast.sim != slow.sim {
            return Err(format!("{}: metrics diverge", vp.name()));
        }
        Ok(())
    });
}

#[test]
fn fuzz_delta_patch_vs_recompile() {
    drive("fuzz_delta_patch_vs_recompile", 0xD3, 6, |x| {
        let g = fuzz_graph(x, 10, 80);
        let seed = x.next_u64();
        let cfg = ArchConfig::default();
        let c0 = compile(&g, &cfg, &CompileOpts { seed, ..Default::default() });
        let delta = fuzz_delta(x, &g);
        let mut g2 = g.clone();
        g2.apply_delta(&delta)?;
        let mut patched = c0.clone();
        patched.apply_attr_updates(&delta)?;
        let full = compile(&g2, &cfg, &CompileOpts { seed, ..Default::default() });
        let src = x.below(g.num_vertices() as u64) as u32;
        let a = flipsim::run(&patched, Workload::Sssp, src, &SimOptions::default())
            .map_err(|e| e.to_string())?;
        let b = flipsim::run(&full, Workload::Sssp, src, &SimOptions::default())
            .map_err(|e| e.to_string())?;
        if a.cycles != b.cycles || a.attrs != b.attrs || a.sim != b.sim {
            return Err("patched tables diverge from full recompile".into());
        }
        if a.attrs != reference::dijkstra(&g2, src) {
            return Err("patched run diverges from oracle on new weights".into());
        }
        Ok(())
    });
}

#[test]
fn fuzz_ann_fused_vs_sequential_vs_oracle() {
    drive("fuzz_ann_fused_vs_sequential_vs_oracle", 0xA7, 4, |x| {
        let n = 24 + x.below(56) as usize;
        let (g, emb) = generate::ann_graph(n, 8, 6, x.next_u64());
        let cfg = ArchConfig::default();
        let c =
            compile(&g, &cfg, &CompileOpts { seed: x.next_u64(), ..Default::default() });
        let params = AnnParams {
            k: 2 + x.below(4) as usize,
            beam: 6 + x.below(10) as usize,
            ..AnnParams::default()
        };
        let lanes = 1 + x.below(4) as usize;
        let queries: Vec<AnnQuery> = (0..lanes)
            .map(|_| {
                let q = emb.vector(x.below(n as u64) as u32).to_vec();
                // duplicate entry points are legal — dedup is the search's job
                let entries: Vec<u32> =
                    (0..1 + x.below(3)).map(|_| x.below(n as u64) as u32).collect();
                (q, entries)
            })
            .collect();
        let opts = SimOptions::default();
        let mut batch = BatchInstance::new(&c, lanes);
        let fused = ann::search_batch(&mut batch, &c, &g, &emb, &queries, &params, &opts);
        for (i, ((q, entries), f)) in queries.iter().zip(fused).enumerate() {
            let f = f.map_err(|e| format!("fused lane {i}: {e}"))?;
            let seq = ann::search(&c, &g, &emb, q, entries, &params, &opts)
                .map_err(|e| format!("sequential query {i}: {e}"))?;
            if f != seq {
                return Err(format!("query {i}: fused lane diverges from sequential"));
            }
            let want = reference::beam_search(&g, &emb, q, entries, params.beam, params.k);
            if f.neighbors != want.neighbors
                || f.attrs != want.attrs
                || f.supersteps != want.supersteps
            {
                return Err(format!("query {i}: fabric diverges from the CPU oracle"));
            }
        }
        Ok(())
    });
}

#[test]
fn fuzz_engine_job_mixes() {
    drive("fuzz_engine_job_mixes", 0x90, 4, |x| {
        use flip::experiments::harness::{CompiledPair, ShardedPair};
        use flip::service::{Engine, Job};
        let g = fuzz_graph(x, 12, 48);
        let seed = x.next_u64();
        let cfg = ArchConfig::default();
        let n = g.num_vertices() as u64;
        let jobs: Vec<Job> = (0..x.range(3, 9))
            .map(|_| {
                let s = x.below(n) as u32;
                let t = x.below(n) as u32;
                match x.below(4) {
                    0 => Job::Workload(Workload::Bfs, s),
                    1 => Job::Workload(Workload::Sssp, s),
                    2 => Job::Workload(Workload::Wcc, s),
                    _ => Job::Navigate { source: s, target: t },
                }
            })
            .collect();
        let pair = CompiledPair::build(&g, &cfg, seed);
        let spair = ShardedPair::build(&g, 1 + x.below(3) as usize, &cfg, seed);
        let mut single = Engine::new(&pair).with_workers(2).with_navigation(3);
        let mut sharded = Engine::new_sharded(&spair).with_workers(2).with_navigation(3);
        let a = single.serve(&jobs);
        let b = sharded.serve(&jobs);
        for (i, (ra, rb)) in a.results.iter().zip(&b.results).enumerate() {
            match (ra, rb) {
                (Ok(qa), Ok(qb)) => {
                    if qa.run.attrs != qb.run.attrs {
                        return Err(format!("job {i} ({:?}): attrs diverge", jobs[i]));
                    }
                    if qa.distance != qb.distance {
                        return Err(format!("job {i}: distances diverge"));
                    }
                }
                (Err(_), Err(_)) => {}
                _ => return Err(format!("job {i}: one engine failed, the other did not")),
            }
        }
        Ok(())
    });
}

//! Beam-search ANN oracle-differential battery (DESIGN.md §10).
//!
//! Three layers of guarantees:
//!
//! * **bitwise triple equality** — the event-driven core, the naive
//!   cycle-stepped reference core, and the CPU beam-search oracle
//!   ([`reference::beam_search`]) must agree on neighbors, final
//!   attributes and superstep count for every query; the two fabric
//!   backends must additionally agree on every metric (cycles,
//!   deliveries, activity). The same equality must survive fused
//!   [`BatchInstance`] lanes (B ∈ {1, 2, 8}), multi-chip sharding
//!   (K ∈ {1, 2, 4}, pooled or serial supersteps) and a slice-swapping
//!   machine too small to hold the graph resident;
//! * **recall@10 ≥ 0.9** — a seeded property over clustered embeddings:
//!   recall is a function of (embeddings, graph, beam, entry seeding)
//!   only, because the fabric reproduces the oracle bitwise;
//! * **hierarchy handoff** — a degenerate single-level [`AnnIndex`]
//!   driven through the resume-port searcher ([`AnnSearcher`]) must
//!   reproduce the flat [`ann::search`] answer bitwise, and a real
//!   two-level index must return well-formed base-graph neighbors.
//!
//! Randomized suites derive from one 64-bit seed; on failure the panic
//! names it. Re-run just that case with
//! `FLIP_ANN_SEED=0x<seed> cargo test -q --test ann`.

mod common;

use flip::compiler::{compile, CompileOpts};
use flip::config::ArchConfig;
use flip::graph::{generate, reference};
use flip::sim::multichip::ShardedMachine;
use flip::sim::{BatchInstance, SimOptions};
use flip::util::WorkerPool;
use flip::workloads::ann::{self, AnnIndex, AnnParams, AnnQuery, AnnSearcher};

/// xorshift64* — independent of the crate's xoshiro so test inputs
/// cannot covary with any in-crate randomness.
struct XorShift {
    s: u64,
}

impl XorShift {
    fn new(seed: u64) -> XorShift {
        XorShift { s: seed | 1 }
    }

    fn next_u64(&mut self) -> u64 {
        let mut x = self.s;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.s = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }
}

/// The per-suite seed list: `cases` seeds derived from `salt`, or just
/// the user's `FLIP_ANN_SEED` when set (the one-line repro path).
fn seeds(salt: u64, cases: usize) -> Vec<u64> {
    if let Ok(s) = std::env::var("FLIP_ANN_SEED") {
        let s = s.trim();
        let parsed = match s.strip_prefix("0x") {
            Some(h) => u64::from_str_radix(h, 16),
            None => s.parse::<u64>(),
        };
        return vec![parsed.unwrap_or_else(|_| panic!("bad FLIP_ANN_SEED `{s}`"))];
    }
    let mut x = XorShift::new(0xA22 ^ salt);
    (0..cases).map(|_| x.next_u64()).collect()
}

/// Run one randomized case, panicking with the repro seed on failure.
fn drive(name: &str, salt: u64, cases: usize, f: impl Fn(&mut XorShift) -> Result<(), String>) {
    for seed in seeds(salt, cases) {
        let mut x = XorShift::new(seed);
        if let Err(msg) = f(&mut x) {
            panic!(
                "ann battery `{name}` failed: {msg}\n  one-line repro: \
                 FLIP_ANN_SEED={seed:#x} cargo test -q --test ann {name}"
            );
        }
    }
}

fn opts() -> SimOptions {
    SimOptions { max_cycles: 2_000_000_000, watchdog: 5_000_000, ..Default::default() }
}

/// Assert the oracle-facing half of an [`ann::AnnResult`] matches the
/// CPU beam search bitwise (neighbors, attrs, supersteps).
fn assert_oracle(got: &ann::AnnResult, want: &reference::BeamTrace, what: &str) {
    assert_eq!(got.neighbors, want.neighbors, "{what}: neighbors diverge from oracle");
    assert_eq!(got.attrs, want.attrs, "{what}: attrs diverge from oracle");
    assert_eq!(got.supersteps, want.supersteps, "{what}: supersteps diverge from oracle");
}

// ---- 1. bitwise triple equality across every backend --------------------

/// Event core ≡ naive reference core ≡ CPU oracle, then the same answer
/// through fused batch lanes (B ∈ {1, 2, 8}) and sharded fabrics
/// (K ∈ {1, 2, 4}, serial and pooled supersteps). Metric-level equality
/// (full [`ann::AnnResult`], cycles included) is asserted wherever the
/// design promises it: naive vs event, lanes vs sequential, pool vs
/// serial, and K = 1 vs single-chip.
#[test]
fn triple_equality_across_lanes_and_shards() {
    let (g, emb) = generate::ann_graph(64, 8, 6, 29);
    let cfg = ArchConfig::default();
    let c = compile(&g, &cfg, &CompileOpts::default());
    let params = AnnParams { beam: 12, k: 6, ..AnnParams::default() };
    let queries: Vec<AnnQuery> = [7u32, 19, 42, 63, 7, 30, 55, 11]
        .iter()
        .map(|&v| {
            let q = emb.vector(v).to_vec();
            let entries = vec![0u32, (v + 1) % 64, 5];
            (q, entries)
        })
        .collect();

    // sequential event core vs naive core vs oracle, per query
    let mut sequential = Vec::new();
    for (q, entries) in &queries {
        let want = reference::beam_search(&g, &emb, q, entries, params.beam, params.k);
        let got = ann::search(&c, &g, &emb, q, entries, &params, &opts())
            .unwrap_or_else(|e| panic!("event-core search failed: {e}"));
        assert_oracle(&got, &want, "event core");
        let slow = ann::search_naive(&c, &g, &emb, q, entries, &params, &opts())
            .unwrap_or_else(|e| panic!("naive-core search failed: {e}"));
        assert_eq!(slow, got, "naive core diverges from event core (metrics included)");
        sequential.push(got);
    }

    // fused lanes: each lane bitwise equal to its sequential run
    for lanes in [1usize, 2, 8] {
        let mut batch = BatchInstance::new(&c, lanes);
        for (ci, chunk) in queries.chunks(lanes).enumerate() {
            let out = ann::search_batch(&mut batch, &c, &g, &emb, chunk, &params, &opts());
            for (li, r) in out.into_iter().enumerate() {
                let r = r.unwrap_or_else(|e| panic!("B={lanes} lane {li} failed: {e}"));
                assert_eq!(
                    r,
                    sequential[ci * lanes + li],
                    "B={lanes} lane {li}: fused run diverges from sequential"
                );
            }
        }
    }

    // sharded fabric: oracle equality at every K; pool ≡ serial bitwise;
    // K = 1 metric-identical to the single-chip event core
    let pool = WorkerPool::new(2);
    for k in [1usize, 2, 4] {
        let m = ShardedMachine::build(&g, k, &cfg, 29);
        let mut insts = m.new_instances();
        for ((q, entries), want) in queries.iter().zip(&sequential) {
            let serial =
                ann::search_sharded(&m, &mut insts, &g, &emb, q, entries, &params, &opts(), None)
                    .unwrap_or_else(|e| panic!("K={k} serial search failed: {e}"));
            assert_eq!(serial.neighbors, want.neighbors, "K={k}: neighbors diverge");
            assert_eq!(serial.attrs, want.attrs, "K={k}: attrs diverge");
            assert_eq!(serial.supersteps, want.supersteps, "K={k}: supersteps diverge");
            let pooled = ann::search_sharded(
                &m,
                &mut insts,
                &g,
                &emb,
                q,
                entries,
                &params,
                &opts(),
                Some(&pool),
            )
            .unwrap_or_else(|e| panic!("K={k} pooled search failed: {e}"));
            assert_eq!(pooled, serial, "K={k}: pooled supersteps diverge from serial");
            if k == 1 {
                assert_eq!(serial, *want, "K=1 must be metric-identical to single-chip");
            }
        }
    }
}

/// The same triple equality on a machine too small to hold the graph
/// resident, so every superstep crosses the slice-swapping path: a
/// 4×4 array with 2-deep DRFs (capacity 32) serving a 48-vertex graph.
#[test]
fn triple_equality_survives_slice_swapping() {
    let cfg = ArchConfig { array_w: 4, array_h: 4, drf_size: 2, ..ArchConfig::default() };
    let (g, emb) = generate::ann_graph(48, 8, 6, 31);
    let c = compile(&g, &cfg, &CompileOpts::default());
    assert!(c.placement.num_copies > 1, "fixture must actually swap");
    let params = AnnParams { beam: 8, k: 4, ..AnnParams::default() };
    let queries: Vec<AnnQuery> =
        [3u32, 27, 44].iter().map(|&v| (emb.vector(v).to_vec(), vec![0u32, 9])).collect();
    let mut sequential = Vec::new();
    for (q, entries) in &queries {
        let want = reference::beam_search(&g, &emb, q, entries, params.beam, params.k);
        let got = ann::search(&c, &g, &emb, q, entries, &params, &opts())
            .unwrap_or_else(|e| panic!("swapping search failed: {e}"));
        assert_oracle(&got, &want, "swapping event core");
        let slow = ann::search_naive(&c, &g, &emb, q, entries, &params, &opts())
            .unwrap_or_else(|e| panic!("swapping naive search failed: {e}"));
        assert_eq!(slow, got, "swapping: naive diverges from event core");
        sequential.push(got);
    }
    let mut batch = BatchInstance::new(&c, queries.len());
    let out = ann::search_batch(&mut batch, &c, &g, &emb, &queries, &params, &opts());
    for (i, r) in out.into_iter().enumerate() {
        let r = r.unwrap_or_else(|e| panic!("swapping lane {i} failed: {e}"));
        assert_eq!(r, sequential[i], "swapping lane {i}: fused diverges from sequential");
    }
}

// ---- 2. recall@10 as a seeded property ----------------------------------

/// On clustered embeddings with a generous beam, hash-seeded beam search
/// must recover ≥ 0.9 of the exact 10-NN on average. Recall is measured
/// on the fabric's answer (not the oracle's), so this doubles as an
/// end-to-end sanity check of the full index → probe → search pipeline.
#[test]
fn recall_at_10_meets_threshold_on_seeded_indexes() {
    drive("recall_at_10_meets_threshold_on_seeded_indexes", 0x2EC0, 3, |x| {
        let n = 96 + x.below(97) as usize; // 96..=192
        let (g, emb) = generate::ann_graph(n, 8, 6, x.next_u64());
        let params = AnnParams { k: 10, beam: 64, ..AnnParams::default() };
        let ix = AnnIndex::build(&g, &emb, 1, &ArchConfig::default(), x.next_u64(), params);
        let mut searcher = AnnSearcher::new(&ix);
        let queries = 5usize;
        let mut total = 0.0f64;
        for _ in 0..queries {
            let qv = emb.vector(x.below(n as u64) as u32).to_vec();
            let r = searcher
                .search(&ix, &qv, &opts())
                .map_err(|e| format!("seeded search failed: {e}"))?;
            total += reference::recall(&r.neighbors, &reference::knn_exact(&emb, &qv, params.k));
        }
        let mean = total / queries as f64;
        if mean < 0.9 {
            return Err(format!("mean recall@10 {mean:.3} < 0.9 over {queries} queries (|V|={n})"));
        }
        Ok(())
    });
}

// ---- 3. hierarchy handoff -----------------------------------------------

/// A single-level index driven through the resume-port searcher must
/// reproduce the flat dense-seeded search bitwise on everything the
/// oracle sees (neighbors, attrs, supersteps) — the handoff's `Inject`
/// dedup rule must be semantically invisible.
#[test]
fn degenerate_hierarchy_matches_flat_search() {
    let (g, emb) = generate::ann_graph(80, 8, 6, 37);
    let params = AnnParams { beam: 10, k: 5, ..AnnParams::default() };
    let ix = AnnIndex::build(&g, &emb, 1, &ArchConfig::default(), 37, params);
    assert_eq!(ix.levels.len(), 1, "degenerate build must stay single-level");
    let mut searcher = AnnSearcher::new(&ix);
    for v in [2u32, 41, 79] {
        let qv = emb.vector(v).to_vec();
        let entries = ix.probe(&qv);
        let flat = ann::search(&ix.base().compiled, &g, &emb, &qv, &entries, &params, &opts())
            .unwrap_or_else(|e| panic!("flat search failed: {e}"));
        let via = searcher
            .search(&ix, &qv, &opts())
            .unwrap_or_else(|e| panic!("searcher failed: {e}"));
        assert_eq!(via.neighbors, flat.neighbors, "query {v}: neighbors diverge");
        assert_eq!(via.attrs, flat.attrs, "query {v}: attrs diverge");
        assert_eq!(via.supersteps, flat.supersteps, "query {v}: supersteps diverge");
        let want = reference::beam_search(&g, &emb, &qv, &entries, params.beam, params.k);
        assert_oracle(&flat, &want, "flat search");
    }
}

/// A real two-level hierarchy: the coarse level's winners seed the base
/// level through the resume port. The answer must be well-formed
/// base-graph neighbors — exact distances, ascending `(dist, vid)`
/// order, `k` rows — and must cost supersteps on both levels.
#[test]
fn two_level_hierarchy_returns_well_formed_base_answers() {
    let (g, emb) = generate::ann_graph(256, 8, 6, 43);
    let params = AnnParams { k: 8, beam: 24, ..AnnParams::default() };
    let ix = AnnIndex::build(&g, &emb, 2, &ArchConfig::default(), 43, params);
    assert_eq!(ix.levels.len(), 2, "256 vertices coarsen to one upper level");
    let mut searcher = AnnSearcher::new(&ix);
    let mut x = XorShift::new(0xB0B);
    for _ in 0..4 {
        let qv = emb.vector(x.below(256) as u32).to_vec();
        let r = searcher
            .search(&ix, &qv, &opts())
            .unwrap_or_else(|e| panic!("hierarchical search failed: {e}"));
        assert_eq!(r.neighbors.len(), params.k, "k rows");
        for w in r.neighbors.windows(2) {
            assert!(
                (w[0].1, w[0].0) < (w[1].1, w[1].0),
                "neighbors must ascend by (dist, vid): {:?}",
                r.neighbors
            );
        }
        for &(v, d) in &r.neighbors {
            assert!((v as usize) < 256, "neighbor {v} must be a base-graph id");
            assert_eq!(d, emb.dist_to(v, &qv), "neighbor {v}: stored distance must be exact");
            assert_eq!(r.attrs[v as usize], d, "neighbor {v}: attr is its distance");
        }
        // the coarse pass costs at least one superstep before the handoff
        assert!(r.supersteps >= 2, "two live levels must cost ≥ 2 supersteps");
        assert!(r.cycles > 0 && r.delivered > 0);
    }
}

// ---- 4. ANN through the shared random-program factory -------------------

/// The shared test-helper factory's ANN case (`which = 6`) must agree
/// with the oracle hook like every other program — the same differential
/// the fuzz suite runs, pinned here on one seed.
#[test]
fn factory_ann_case_matches_its_reference_hook() {
    let mut x = XorShift::new(0x77AA);
    let g = common::random_graph(&mut |n| x.below(n), 24, 48);
    let cfg = ArchConfig::default();
    let (vp, view, src) = common::program_case(6, &g, &mut |n| x.below(n));
    let c = compile(&view, &cfg, &CompileOpts::default());
    let r = flip::sim::flip::run_program(&c, vp.as_ref(), src, &opts())
        .unwrap_or_else(|e| panic!("factory ANN case failed: {e}"));
    assert_eq!(r.attrs, vp.reference(&view, src), "factory ANN superstep vs oracle");
}

//! Failure-path coverage: aborts surface as data (never panics), the
//! engine stays serviceable afterwards, and `Delta` edge cases behave —
//! empty deltas are no-ops, duplicate reweights are last-wins, unknown
//! arcs are rejected atomically.

use flip::compiler::{compile, CompileOpts};
use flip::config::ArchConfig;
use flip::experiments::harness::{CompiledPair, ShardedPair};
use flip::graph::{generate, reference, Delta};
use flip::service::{Engine, Job};
use flip::sim::flip::{SimInstance, SimOptions};
use flip::workloads::Workload;

fn tiny_opts() -> SimOptions {
    SimOptions { max_cycles: 1, ..Default::default() }
}

#[test]
fn sharded_watchdog_abort_is_a_query_error_and_engine_recovers() {
    let g = generate::road_network(64, 146, 166, 3);
    let cfg = ArchConfig::default();
    let spair = ShardedPair::build(&g, 2, &cfg, 3);
    let mut engine = Engine::new_sharded(&spair).with_workers(2);
    // batch 1: impossible cycle budget — every query aborts inside a
    // shard and must come back as a QueryError value
    engine.set_opts(tiny_opts());
    let jobs = [Job::Workload(Workload::Bfs, 0), Job::Workload(Workload::Sssp, 5)];
    let rep = engine.serve(&jobs);
    assert!(rep.results.iter().all(|r| r.is_err()), "aborts must surface as errors");
    assert!(rep.first_error().unwrap().msg.contains("max_cycles"));
    // batch 2: same engine, sane budget — the worker machines hard-reset
    // and serve exact results
    engine.set_opts(SimOptions::default());
    let rep = engine.serve(&jobs);
    for (r, (w, src)) in rep.results.iter().zip([(Workload::Bfs, 0u32), (Workload::Sssp, 5)]) {
        let q = r.as_ref().unwrap_or_else(|e| panic!("{} still failing: {e}", w.name()));
        let want = match w {
            Workload::Bfs => reference::bfs_levels(&g, src),
            _ => reference::dijkstra(&g, src),
        };
        assert_eq!(q.run.attrs, want, "{} after recovery", w.name());
    }
}

#[test]
fn single_chip_abort_also_recovers_through_the_engine() {
    let g = generate::road_network(48, 100, 120, 5);
    let pair = CompiledPair::build(&g, &ArchConfig::default(), 5);
    let mut engine = Engine::new(&pair).with_workers(1);
    engine.set_opts(tiny_opts());
    let rep = engine.serve(&[Job::Workload(Workload::Bfs, 0)]);
    assert!(rep.results[0].is_err());
    engine.set_opts(SimOptions::default());
    let rep = engine.serve(&[Job::Workload(Workload::Bfs, 0)]);
    assert_eq!(rep.results[0].as_ref().unwrap().run.attrs, reference::bfs_levels(&g, 0));
}

#[test]
fn engine_batch_where_every_job_fails_reports_cleanly() {
    let g = generate::road_network(32, 70, 80, 7);
    let pair = CompiledPair::build(&g, &ArchConfig::default(), 7);
    let mut engine = Engine::new(&pair).with_workers(2);
    let jobs = [
        Job::Workload(Workload::Bfs, 1_000),          // out of range
        Job::Workload(Workload::PageRank, 0),         // not servable
        Job::Workload(Workload::Sssp, 9_999),         // out of range
        Job::Navigate { source: 0, target: 40_000 },  // out of range
    ];
    let rep = engine.serve(&jobs);
    assert_eq!(rep.results.len(), jobs.len());
    assert!(rep.results.iter().all(|r| r.is_err()), "every job must fail as data");
    assert_eq!(rep.sim_cycles, 0, "no successful query, no simulated cycles");
    assert!(rep.queries_per_s.is_finite());
    // the engine still works afterwards
    let ok = engine.serve(&[Job::Workload(Workload::Bfs, 0)]);
    assert!(ok.results[0].is_ok());
}

#[test]
fn empty_delta_is_a_no_op_everywhere() {
    let g = generate::road_network(48, 100, 120, 9);
    let mut pair = CompiledPair::build(&g, &ArchConfig::default(), 9);
    let before = flip::experiments::harness::run_flip(&pair, Workload::Sssp, 0);
    pair.apply_attr_updates(&Delta::new()).unwrap();
    let after = flip::experiments::harness::run_flip(&pair, Workload::Sssp, 0);
    assert_eq!(before.cycles, after.cycles);
    assert_eq!(before.attrs, after.attrs);
    assert_eq!(before.sim, after.sim);
}

#[test]
fn duplicate_reweight_is_last_wins_in_graph_and_tables() {
    let g = generate::road_network(48, 100, 120, 11);
    let (u, v, _) = g.arcs().next().expect("graph has arcs");
    // the same edge named twice: the second write must win in both the
    // host graph and the mapped Intra-Tables
    let delta = Delta::from_edges(&g, &[(u, v, 3), (u, v, 17)]);
    let mut pair = CompiledPair::build(&g, &ArchConfig::default(), 11);
    pair.apply_attr_updates(&delta).unwrap();
    assert!(pair.graph.neighbors(u).any(|e| e == (v, 17)), "host graph last-wins");
    let mut g2 = g.clone();
    g2.apply_delta(&delta).unwrap();
    let r = flip::experiments::harness::run_flip(&pair, Workload::Sssp, 0);
    assert_eq!(r.attrs, reference::dijkstra(&g2, 0), "tables agree with last-wins oracle");
}

#[test]
fn attr_update_racing_a_reused_instance_is_fully_visible() {
    // the slab-invalidation hazard class: a SimInstance borrows table
    // ranges only for the duration of one run (the CompiledGraph slab
    // offsets are private, every read re-derives its CSR range), so a
    // weight patch applied between two queries on the SAME live instance
    // must be completely visible to the second query — no stale ranges,
    // no cached weights
    let g = generate::road_network(48, 100, 120, 17);
    let cfg = ArchConfig::default();
    let copts = CompileOpts { seed: 17, ..Default::default() };
    let mut c = compile(&g, &cfg, &copts);
    let mut inst = SimInstance::new(&c);
    let before = inst.run(&c, Workload::Sssp, 0, &SimOptions::default()).unwrap();
    assert_eq!(before.attrs, reference::dijkstra(&g, 0));
    // reweight a subset of the edges while the instance stays live
    let changes: Vec<(u32, u32, u32)> = g
        .arcs()
        .filter(|&(u, v, _)| u < v && (u + v) % 2 == 0)
        .map(|(u, v, w)| (u, v, w + 5))
        .collect();
    assert!(!changes.is_empty());
    let delta = Delta::from_edges(&g, &changes);
    let mut g2 = g.clone();
    g2.apply_delta(&delta).unwrap();
    c.apply_attr_updates(&delta).unwrap();
    let after = inst.run(&c, Workload::Sssp, 0, &SimOptions::default()).unwrap();
    assert_eq!(after.attrs, reference::dijkstra(&g2, 0), "stale table data served after patch");
    // and the reused instance over the patched slab is bit-identical to a
    // cold machine over a full recompile of the reweighted graph
    let full = compile(&g2, &cfg, &copts);
    let fresh = flip::sim::flip::run(&full, Workload::Sssp, 0, &SimOptions::default()).unwrap();
    assert_eq!(after.cycles, fresh.cycles);
    assert_eq!(after.attrs, fresh.attrs);
    assert_eq!(after.sim, fresh.sim);
}

#[test]
fn rejected_delta_leaves_the_slab_bitwise_untouched() {
    // failure path of the same hazard class: a delta that fails
    // validation mid-batch must leave the live slab byte-identical — the
    // next query on a reused instance reproduces the pre-delta run exactly
    let g = generate::road_network(48, 100, 120, 19);
    let cfg = ArchConfig::default();
    let mut c = compile(&g, &cfg, &CompileOpts { seed: 19, ..Default::default() });
    let mut inst = SimInstance::new(&c);
    let before = inst.run(&c, Workload::Sssp, 0, &SimOptions::default()).unwrap();
    let (u, v, _) = g.arcs().next().unwrap();
    let missing = (0..48u32)
        .flat_map(|a| (0..48u32).map(move |b| (a, b)))
        .find(|&(a, b)| a != b && !g.neighbors(a).any(|(t, _)| t == b))
        .expect("sparse graph has a missing arc");
    let mut delta = Delta::new();
    delta.reweight(&g, u, v, 999); // valid change...
    delta.reweight(&g, missing.0, missing.1, 1); // ...then an invalid one
    assert!(c.apply_attr_updates(&delta).is_err());
    let after = inst.run(&c, Workload::Sssp, 0, &SimOptions::default()).unwrap();
    assert_eq!(before.cycles, after.cycles, "rejected delta changed the machine");
    assert_eq!(before.attrs, after.attrs);
    assert_eq!(before.sim, after.sim);
}

#[test]
fn unknown_arc_in_a_delta_is_rejected_atomically() {
    let g = generate::road_network(32, 70, 80, 13);
    let mut pair = CompiledPair::build(&g, &ArchConfig::default(), 13);
    let (u, v, w0) = g.arcs().next().unwrap();
    let missing = (0..32u32)
        .flat_map(|a| (0..32u32).map(move |b| (a, b)))
        .find(|&(a, b)| a != b && !g.neighbors(a).any(|(t, _)| t == b))
        .expect("sparse graph has a missing arc");
    let mut delta = Delta::new();
    delta.reweight(&g, u, v, 999); // valid change...
    delta.reweight(&g, missing.0, missing.1, 1); // ...then an invalid one
    let err = pair.apply_attr_updates(&delta).unwrap_err();
    assert!(err.contains("structure"), "{err}");
    // atomic: the valid change must NOT have been applied
    assert!(
        pair.graph.neighbors(u).any(|e| e == (v, w0)),
        "graph must be untouched after a rejected delta"
    );
    let r = flip::experiments::harness::run_flip(&pair, Workload::Sssp, 0);
    assert_eq!(r.attrs, reference::dijkstra(&g, 0), "tables untouched too");
}

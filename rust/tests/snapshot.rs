//! Golden snapshot tests: committed JSON fixtures of cycles, attributes
//! and the integer `SimMetrics` counters for all six workloads on one
//! small fixed graph, compared field-by-field so a regression shows a
//! readable `key: fixture X, run Y` diff instead of a blob mismatch.
//!
//! The fixture lives at `tests/fixtures/golden_runs.json`. When it is
//! absent the test SKIPs visibly (the repo's PJRT-golden pattern) —
//! record it once with a working toolchain:
//!
//! ```text
//! FLIP_SNAPSHOT_WRITE=1 cargo test -q --test snapshot
//! ```
//!
//! The fixture format is a flat JSON object: `"<workload>.<field>"` →
//! integer or integer array. Everything recorded is deterministic
//! (fixed graph, fixed seeds, cycle-exact simulator), so exact equality
//! is the right comparison.

use flip::compiler::{compile, CompileOpts};
use flip::config::ArchConfig;
use flip::graph::{generate, reference, Graph};
use flip::metrics::RunResult;
use flip::sim::flip as flipsim;
use flip::sim::flip::SimOptions;
use flip::workloads::{mis, navigation, pagerank, view_for, Workload};
use std::collections::BTreeMap;
use std::path::PathBuf;

/// A fixture value: one integer or an integer vector (attrs).
#[derive(Debug, Clone, PartialEq, Eq)]
enum Val {
    Num(u64),
    Arr(Vec<u64>),
}

impl Val {
    fn render(&self) -> String {
        match self {
            Val::Num(n) => n.to_string(),
            Val::Arr(v) => {
                let items: Vec<String> = v.iter().map(|n| n.to_string()).collect();
                format!("[{}]", items.join(", "))
            }
        }
    }
}

fn fixture_path() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/golden_runs.json")
}

// ---- minimal flat-JSON reader/writer (no serde offline) -----------------

fn write_fixture(map: &BTreeMap<String, Val>, path: &std::path::Path) -> std::io::Result<()> {
    let mut out = String::from("{\n");
    let last = map.len().saturating_sub(1);
    for (i, (k, v)) in map.iter().enumerate() {
        out.push_str(&format!("  \"{k}\": {}", v.render()));
        out.push_str(if i == last { "\n" } else { ",\n" });
    }
    out.push_str("}\n");
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, out)
}

/// Parse the flat `{"key": int | [int, ...]}` fixture subset. Panics on
/// malformed input — a broken fixture should fail loudly, not skip.
fn parse_fixture(text: &str) -> BTreeMap<String, Val> {
    let mut map = BTreeMap::new();
    let b: Vec<char> = text.chars().collect();
    let mut i = 0usize;
    let skip_ws = |b: &[char], i: &mut usize| {
        while *i < b.len() && b[*i].is_whitespace() {
            *i += 1;
        }
    };
    let parse_num = |b: &[char], i: &mut usize| -> u64 {
        let start = *i;
        while *i < b.len() && b[*i].is_ascii_digit() {
            *i += 1;
        }
        assert!(*i > start, "expected digit at offset {start}");
        b[start..*i].iter().collect::<String>().parse().expect("integer fixture value")
    };
    skip_ws(&b, &mut i);
    assert_eq!(b.get(i), Some(&'{'), "fixture must be a JSON object");
    i += 1;
    loop {
        skip_ws(&b, &mut i);
        match b.get(i) {
            Some('}') => break,
            Some(',') => {
                i += 1;
                continue;
            }
            Some('"') => {}
            other => panic!("unexpected {other:?} at offset {i}"),
        }
        i += 1;
        let kstart = i;
        while b[i] != '"' {
            i += 1;
        }
        let key: String = b[kstart..i].iter().collect();
        i += 1;
        skip_ws(&b, &mut i);
        assert_eq!(b.get(i), Some(&':'), "expected `:` after key {key}");
        i += 1;
        skip_ws(&b, &mut i);
        let val = if b[i] == '[' {
            i += 1;
            let mut items = Vec::new();
            loop {
                skip_ws(&b, &mut i);
                match b[i] {
                    ']' => {
                        i += 1;
                        break;
                    }
                    ',' => i += 1,
                    _ => items.push(parse_num(&b, &mut i)),
                }
            }
            Val::Arr(items)
        } else {
            Val::Num(parse_num(&b, &mut i))
        };
        map.insert(key, val);
    }
    map
}

// ---- the six recorded runs ----------------------------------------------

/// The small fixed graph every snapshot runs on (24 vertices, undirected
/// road network, fixed seed — small enough that a diff of `attrs` is
/// readable).
fn snapshot_graph() -> Graph {
    generate::road_network(24, 50, 58, 0xF11F)
}

fn record(map: &mut BTreeMap<String, Val>, name: &str, r: &RunResult) {
    map.insert(format!("{name}.cycles"), Val::Num(r.cycles));
    map.insert(format!("{name}.edges_traversed"), Val::Num(r.edges_traversed));
    map.insert(
        format!("{name}.attrs"),
        Val::Arr(r.attrs.iter().map(|&a| a as u64).collect()),
    );
    map.insert(format!("{name}.packets_delivered"), Val::Num(r.sim.packets_delivered));
    map.insert(format!("{name}.packets_parked"), Val::Num(r.sim.packets_parked));
    map.insert(format!("{name}.swaps"), Val::Num(r.sim.swaps));
    map.insert(format!("{name}.swap_cycles"), Val::Num(r.sim.swap_cycles));
    map.insert(
        format!("{name}.peak_parallelism"),
        Val::Num(r.sim.peak_parallelism as u64),
    );
    map.insert(format!("{name}.chip_packets"), Val::Num(r.sim.chip_packets));
    map.insert(format!("{name}.chip_link_cycles"), Val::Num(r.sim.chip_link_cycles));
    map.insert(format!("{name}.link_retransmits"), Val::Num(r.sim.link_retransmits));
    map.insert(
        format!("{name}.fault_recovery_cycles"),
        Val::Num(r.sim.fault_recovery_cycles),
    );
    map.insert(format!("{name}.alu_ops"), Val::Num(r.sim.activity.alu_ops));
    map.insert(format!("{name}.intra_lookups"), Val::Num(r.sim.activity.intra_lookups));
    map.insert(format!("{name}.inter_walked"), Val::Num(r.sim.activity.inter_walked));
    map.insert(format!("{name}.switch_grants"), Val::Num(r.sim.activity.switch_grants));
    map.insert(format!("{name}.swap_words"), Val::Num(r.sim.activity.swap_words));
}

/// Run all six workloads on the fixed graph and record every field.
fn current_snapshot() -> BTreeMap<String, Val> {
    let g = snapshot_graph();
    let cfg = ArchConfig::default();
    let copts = CompileOpts::default();
    let opts = SimOptions::default();
    let mut map = BTreeMap::new();
    for w in Workload::ALL {
        let view = view_for(w, &g);
        let c = compile(&view, &cfg, &copts);
        let r = flipsim::run(&c, w, 0, &opts).expect("trio snapshot run");
        record(&mut map, w.name(), &r);
    }
    let c = compile(&g, &cfg, &copts);
    let pr = pagerank::PageRankRound {
        contribs: reference::pagerank_contribs(&g, &reference::pagerank_init(g.num_vertices())),
    };
    let r = flipsim::run_program(&c, &pr, 0, &opts).expect("pagerank snapshot run");
    record(&mut map, "PageRank", &r);
    let astar = navigation::AStar::new(&g, 0, g.num_vertices() as u32 - 1, 3);
    let r = flipsim::run_program(&c, &astar, 0, &opts).expect("astar snapshot run");
    record(&mut map, "A*", &r);
    let (m, mview) = mis::Mis::build(&g, 0xA11CE);
    let cm = compile(&mview, &cfg, &copts);
    let r = flipsim::run_program(&cm, &m, 0, &opts).expect("mis snapshot run");
    record(&mut map, "MIS", &r);
    map
}

#[test]
fn golden_snapshot_all_six_workloads() {
    let path = fixture_path();
    let current = current_snapshot();
    if std::env::var("FLIP_SNAPSHOT_WRITE").is_ok() {
        write_fixture(&current, &path).expect("write fixture");
        eprintln!("recorded snapshot fixture at {}", path.display());
        return;
    }
    let Ok(text) = std::fs::read_to_string(&path) else {
        eprintln!(
            "SKIP golden_snapshot_all_six_workloads: no fixture at {} — record one with \
             FLIP_SNAPSHOT_WRITE=1 cargo test -q --test snapshot",
            path.display()
        );
        return;
    };
    let fixture = parse_fixture(&text);
    let mut diffs = Vec::new();
    for (k, want) in &fixture {
        match current.get(k) {
            None => diffs.push(format!("{k}: in fixture but not produced by the run")),
            Some(got) if got != want => {
                diffs.push(format!("{k}: fixture {}, run {}", want.render(), got.render()))
            }
            _ => {}
        }
    }
    for k in current.keys() {
        if !fixture.contains_key(k) {
            diffs.push(format!(
                "{k}: produced by the run but missing from the fixture (re-record?)"
            ));
        }
    }
    assert!(
        diffs.is_empty(),
        "golden snapshot diverged ({} fields):\n  {}",
        diffs.len(),
        diffs.join("\n  ")
    );
}

#[test]
fn fixture_parser_roundtrips() {
    // the reader/writer pair is itself tested so a future fixture is
    // trusted infrastructure, not hope
    let mut map = BTreeMap::new();
    map.insert("BFS.cycles".to_string(), Val::Num(123));
    map.insert("BFS.attrs".to_string(), Val::Arr(vec![0, 4294967295, 7]));
    map.insert("MIS.swaps".to_string(), Val::Num(0));
    let tmp = std::env::temp_dir().join(format!("flip_snapshot_test_{}.json", std::process::id()));
    write_fixture(&map, &tmp).unwrap();
    let text = std::fs::read_to_string(&tmp).unwrap();
    let parsed = parse_fixture(&text);
    std::fs::remove_file(&tmp).ok();
    assert_eq!(parsed, map);
}

//! Shared integration-test helpers: the random-graph builder and the
//! seven-workload program factory used by the sharding battery
//! (`tests/sharded.rs`), the fault battery (`tests/fault.rs`) and the
//! fuzz suite (`tests/fuzz.rs`).
//!
//! Everything is parameterized over a `draw(n) -> uniform in [0, n)`
//! closure, so each suite keeps its own independent RNG (xoshiro for the
//! property battery, xorshift64* for the fuzzer) while the graph/program
//! construction logic exists exactly once — adding a workload here
//! extends every suite's coverage at the same time.
#![allow(dead_code)] // each test bin compiles its own copy

use flip::arch::isa::{self, Instr};
use flip::graph::embed::Embeddings;
use flip::graph::{reference, Graph, INF};
use flip::workloads::program::VertexProgram;
use flip::workloads::{mis, navigation, pagerank, view_for, Workload};

/// One workload case: (program, compiled view, source).
pub type ProgramCase = (Box<dyn VertexProgram>, Graph, u32);

/// Uniform-draw closure: `draw(n)` must return a value in `[0, n)`.
pub type Draw<'a> = &'a mut dyn FnMut(u64) -> u64;

/// Random connected weighted undirected graph with |V| in [lo, hi]: a
/// random spanning tree (connectivity, so A*/ALT landmarks apply) plus
/// up to 2·|V| extra edges.
pub fn random_graph(draw: Draw<'_>, lo: usize, hi: usize) -> Graph {
    let n = lo + draw((hi - lo + 1) as u64) as usize;
    let extra = draw(2 * n as u64) as usize;
    let mut edges = Vec::with_capacity(n - 1 + extra);
    for v in 1..n as u32 {
        let p = draw(v as u64) as u32;
        edges.push((p, v, 1 + draw(9) as u32));
    }
    for _ in 0..extra {
        let u = draw(n as u64) as u32;
        let v = draw(n as u64) as u32;
        if u != v {
            edges.push((u, v, 1 + draw(9) as u32));
        }
    }
    Graph::from_edges(n, &edges, false)
}

/// One ANN beam-search expansion superstep with owned state — the
/// seventh factory workload. [`flip::workloads::ann::BeamStep`] borrows
/// its embedding table, so the factory's boxed-`'static` contract needs
/// this owning mirror; every hook delegates to the same ISA
/// ([`isa::PROG_ANN`]) and the same oracle
/// ([`reference::beam_superstep`]), so the differential suites exercise
/// the identical fabric semantics: dense seeding from the expand set,
/// the frozen radius in the bound register, receiver-local distances in
/// the aux lane, and no re-scatter.
#[derive(Debug, Clone)]
pub struct OwnedBeamStep {
    /// Per-vertex embedding table.
    pub emb: Embeddings,
    /// The query vector.
    pub query: Vec<u8>,
    /// Attribute state entering the superstep.
    pub attrs: Vec<u32>,
    /// This superstep's expand set.
    pub expand: Vec<bool>,
    /// Beam radius frozen at superstep entry.
    pub radius: u32,
}

impl VertexProgram for OwnedBeamStep {
    fn name(&self) -> &'static str {
        "ANN"
    }

    fn isa(&self) -> &[Instr] {
        isa::PROG_ANN
    }

    fn init_attr(&self, vid: u32, _n: usize) -> u32 {
        self.attrs[vid as usize]
    }

    fn combine(&self, _attr: u32, _weight: u32) -> u32 {
        0
    }

    fn aux(&self, vid: u32) -> u32 {
        self.emb.dist_to(vid, &self.query)
    }

    fn bound(&self) -> u32 {
        self.radius
    }

    fn single_source(&self) -> bool {
        false
    }

    fn seeds(&self, vid: u32) -> bool {
        self.expand[vid as usize]
    }

    fn announces(&self, _vid: u32, _attr: u32) -> bool {
        false
    }

    fn reference(&self, view: &Graph, _source: u32) -> Vec<u32> {
        reference::beam_superstep(view, &self.emb, &self.query, &self.attrs, &self.expand, self.radius)
    }
}

/// Build workload case `which % 7` for `g`: the paper trio, then
/// PageRank round / A* / MIS / one ANN beam superstep. Returns
/// (program, compiled view, source).
pub fn program_case(which: u64, g: &Graph, draw: Draw<'_>) -> ProgramCase {
    let n = g.num_vertices() as u64;
    let src = draw(n) as u32;
    match which % 7 {
        0 => (Workload::Bfs.builtin_program(), g.clone(), src),
        1 => (Workload::Sssp.builtin_program(), g.clone(), src),
        2 => (Workload::Wcc.builtin_program(), view_for(Workload::Wcc, g), src),
        3 => {
            let contribs =
                reference::pagerank_contribs(g, &reference::pagerank_init(g.num_vertices()));
            (Box::new(pagerank::PageRankRound { contribs }), g.clone(), 0)
        }
        4 => {
            let tgt = draw(n) as u32;
            (Box::new(navigation::AStar::new(g, src, tgt, 3)), g.clone(), src)
        }
        5 => {
            let (m, view) = mis::Mis::build(g, draw(u64::MAX));
            (Box::new(m), view, 0)
        }
        _ => {
            // a mid-search beam superstep: a few discovered entry
            // candidates expand at once under a drawn radius
            let nv = g.num_vertices();
            let emb = Embeddings::clustered(nv, 8, 4, draw(u64::MAX));
            let query = emb.vector(src).to_vec();
            let mut attrs = vec![INF; nv];
            let mut expand = vec![false; nv];
            let mut worst = 0u32;
            for _ in 0..1 + draw(4) {
                let e = draw(nv as u64) as u32;
                let d = emb.dist_to(e, &query);
                attrs[e as usize] = d;
                expand[e as usize] = true;
                worst = worst.max(d);
            }
            // half the cases prune against the worst seeded distance,
            // half run unbounded — both sides of HaltGtBound
            let radius = if draw(2) == 0 { INF } else { worst };
            (Box::new(OwnedBeamStep { emb, query, attrs, expand, radius }), g.clone(), src)
        }
    }
}

/// All seven workload programs for one (undirected) graph.
pub fn all_programs(g: &Graph, draw: Draw<'_>) -> Vec<ProgramCase> {
    (0..7).map(|which| program_case(which, g, &mut *draw)).collect()
}

//! Shared integration-test helpers: the random-graph builder and the
//! six-workload program factory used by both the sharding battery
//! (`tests/sharded.rs`) and the fuzz suite (`tests/fuzz.rs`).
//!
//! Everything is parameterized over a `draw(n) -> uniform in [0, n)`
//! closure, so each suite keeps its own independent RNG (xoshiro for the
//! property battery, xorshift64* for the fuzzer) while the graph/program
//! construction logic exists exactly once — adding a seventh workload
//! here extends both suites' coverage at the same time.
#![allow(dead_code)] // each test bin compiles its own copy

use flip::graph::{reference, Graph};
use flip::workloads::program::VertexProgram;
use flip::workloads::{mis, navigation, pagerank, view_for, Workload};

/// One workload case: (program, compiled view, source).
pub type ProgramCase = (Box<dyn VertexProgram>, Graph, u32);

/// Uniform-draw closure: `draw(n)` must return a value in `[0, n)`.
pub type Draw<'a> = &'a mut dyn FnMut(u64) -> u64;

/// Random connected weighted undirected graph with |V| in [lo, hi]: a
/// random spanning tree (connectivity, so A*/ALT landmarks apply) plus
/// up to 2·|V| extra edges.
pub fn random_graph(draw: Draw<'_>, lo: usize, hi: usize) -> Graph {
    let n = lo + draw((hi - lo + 1) as u64) as usize;
    let extra = draw(2 * n as u64) as usize;
    let mut edges = Vec::with_capacity(n - 1 + extra);
    for v in 1..n as u32 {
        let p = draw(v as u64) as u32;
        edges.push((p, v, 1 + draw(9) as u32));
    }
    for _ in 0..extra {
        let u = draw(n as u64) as u32;
        let v = draw(n as u64) as u32;
        if u != v {
            edges.push((u, v, 1 + draw(9) as u32));
        }
    }
    Graph::from_edges(n, &edges, false)
}

/// Build workload case `which % 6` for `g`: the paper trio, then
/// PageRank round / A* / MIS. Returns (program, compiled view, source).
pub fn program_case(which: u64, g: &Graph, draw: Draw<'_>) -> ProgramCase {
    let n = g.num_vertices() as u64;
    let src = draw(n) as u32;
    match which % 6 {
        0 => (Workload::Bfs.builtin_program(), g.clone(), src),
        1 => (Workload::Sssp.builtin_program(), g.clone(), src),
        2 => (Workload::Wcc.builtin_program(), view_for(Workload::Wcc, g), src),
        3 => {
            let contribs =
                reference::pagerank_contribs(g, &reference::pagerank_init(g.num_vertices()));
            (Box::new(pagerank::PageRankRound { contribs }), g.clone(), 0)
        }
        4 => {
            let tgt = draw(n) as u32;
            (Box::new(navigation::AStar::new(g, src, tgt, 3)), g.clone(), src)
        }
        _ => {
            let (m, view) = mis::Mis::build(g, draw(u64::MAX));
            (Box::new(m), view, 0)
        }
    }
}

/// All six workload programs for one (undirected) graph.
pub fn six_programs(g: &Graph, draw: Draw<'_>) -> Vec<ProgramCase> {
    (0..6).map(|which| program_case(which, g, &mut *draw)).collect()
}

//! Batched-simulation battery (DESIGN.md §Perf.2): the fused multi-lane
//! pass and the pooled lockstep supersteps are *performance* features,
//! so their whole contract is bitwise equality with the sequential
//! paths they replace. This suite proves it with the in-house property
//! harness (`flip::util::proptest`):
//!
//! - `prop_batched_equals_sequential` — all seven workload programs
//!   (trio + PageRank round / A* / MIS / ANN superstep) × B ∈ {1, 2, 8}
//!   lanes: every
//!   lane of a fused [`BatchInstance`] pass must match its own
//!   sequential run on attrs, per-lane cycles, edges traversed, and
//!   every `SimMetrics` counter.
//! - the same invariant across the slice-swapping configs (graphs big
//!   enough to replicate), where the fast-forward interleave is busiest;
//! - a lane-abort case: a batch whose lanes all trip `max_cycles` must
//!   leave the lane bank reusable, with the next batch still bit-exact;
//! - `prop_pooled_supersteps_equal_serial` — K ∈ {1, 2, 4} shards × the
//!   trio workloads: `multichip::run_on` with a [`WorkerPool`] must be
//!   bitwise identical to the serial `multichip::run` merge (cycles,
//!   attrs, metrics, per-shard busy cycles, superstep count).

mod common;

use flip::compiler::{compile, CompileOpts};
use flip::config::ArchConfig;
use flip::prop_assert;
use flip::sim::flip::{self as flipsim, SimOptions};
use flip::sim::multichip::{self, ShardedMachine};
use flip::sim::{BatchInstance, SimError};
use flip::util::{proptest::check, Rng, WorkerPool};
use flip::workloads::program::VertexProgram;
use flip::workloads::Workload;

#[test]
fn prop_batched_equals_sequential() {
    check("batched_equals_sequential", 16, |rng| {
        let g = common::random_graph(&mut |n| rng.below(n), 8, 90);
        let cfg = ArchConfig::default();
        let copts = CompileOpts { seed: rng.next_u64(), ..Default::default() };
        let b = [1usize, 2, 8][rng.below(3) as usize];
        let opts = SimOptions::default();
        let cases = common::all_programs(&g, &mut |n| rng.below(n));
        for (which, (vp, view, src)) in cases.iter().enumerate() {
            let c = compile(view, &cfg, &copts);
            // the trio programs (cases 0-2) are source-parametric, so
            // their lanes get distinct draws; the extended programs
            // embed their roles (A* target, MIS priorities, PageRank
            // contributions), so their lanes repeat the one query
            let n = view.num_vertices() as u64;
            let sources: Vec<u32> = (0..b)
                .map(|i| if which < 3 && i > 0 { rng.below(n) as u32 } else { *src })
                .collect();
            let queries: Vec<(&dyn VertexProgram, u32)> =
                sources.iter().map(|&s| (vp.as_ref(), s)).collect();
            let mut batch = BatchInstance::new(&c, b);
            let fused = batch.run_batch(&c, &queries, &opts);
            for (lane, (&s, f)) in sources.iter().zip(&fused).enumerate() {
                let seq = flipsim::run_program(&c, vp.as_ref(), s, &opts)
                    .map_err(|e| format!("case {which} sequential: {e}"))?;
                let f = f.as_ref().map_err(|e| format!("case {which} lane {lane}: {e}"))?;
                prop_assert!(
                    f.cycles == seq.cycles,
                    "case {} lane {} cycles {} != {}",
                    which,
                    lane,
                    f.cycles,
                    seq.cycles
                );
                prop_assert!(f.attrs == seq.attrs, "case {} lane {} attrs diverge", which, lane);
                prop_assert!(
                    f.edges_traversed == seq.edges_traversed,
                    "case {} lane {} edges {} != {}",
                    which,
                    lane,
                    f.edges_traversed,
                    seq.edges_traversed
                );
                prop_assert!(
                    f.sim == seq.sim,
                    "case {} lane {} metrics diverge: fused {:?} seq {:?}",
                    which,
                    lane,
                    f.sim,
                    seq.sim
                );
            }
        }
        Ok(())
    });
}

#[test]
fn prop_batched_equals_sequential_with_swapping() {
    // same invariant on graphs large enough for slice replication, where
    // each lane's idle-cycle fast-forward interleaves with the others'
    check("batched_equals_sequential_swapping", 4, |rng| {
        let g = common::random_graph(&mut |n| rng.below(n), 260, 380);
        let cfg = ArchConfig::default();
        let c = compile(&g, &cfg, &CompileOpts { seed: rng.next_u64(), ..Default::default() });
        prop_assert!(c.placement.num_copies >= 2, "expected replication");
        let opts =
            SimOptions { max_cycles: 1_000_000_000, watchdog: 5_000_000, ..Default::default() };
        let sources: Vec<u32> =
            (0..4).map(|_| rng.below(g.num_vertices() as u64) as u32).collect();
        let mut batch = BatchInstance::new(&c, sources.len());
        let fused = batch.run_workload_batch(&c, Workload::Sssp, &sources, &opts);
        for (lane, (&s, f)) in sources.iter().zip(&fused).enumerate() {
            let seq = flipsim::run(&c, Workload::Sssp, s, &opts).map_err(|e| e.to_string())?;
            let f = f.as_ref().map_err(|e| format!("lane {lane}: {e}"))?;
            prop_assert!(f.cycles == seq.cycles, "lane {} cycles diverge under swapping", lane);
            prop_assert!(f.attrs == seq.attrs, "lane {} attrs diverge under swapping", lane);
            prop_assert!(f.sim == seq.sim, "lane {} metrics diverge under swapping", lane);
        }
        Ok(())
    });
}

#[test]
fn aborted_lanes_reset_cleanly_for_the_next_batch() {
    let mut rng = Rng::new(0xBA7C);
    let g = common::random_graph(&mut |n| rng.below(n), 40, 60);
    let cfg = ArchConfig::default();
    let c = compile(&g, &cfg, &CompileOpts::default());
    let sources = [0u32, 3, 7];
    let mut batch = BatchInstance::new(&c, sources.len());
    // an impossible cycle budget aborts every lane mid-sweep...
    let tight = SimOptions { max_cycles: 1, ..Default::default() };
    for r in batch.run_workload_batch(&c, Workload::Sssp, &sources, &tight) {
        assert!(matches!(r, Err(SimError::MaxCycles { .. })), "expected a lane abort, got {r:?}");
    }
    // ...and the reused lane bank must still answer the next batch
    // bit-exact, proving aborts leave no residue in lane state
    let opts = SimOptions::default();
    let after = batch.run_workload_batch(&c, Workload::Sssp, &sources, &opts);
    for (&s, f) in sources.iter().zip(&after) {
        let seq = flipsim::run(&c, Workload::Sssp, s, &opts).unwrap();
        let f = f.as_ref().unwrap();
        assert_eq!(f.cycles, seq.cycles, "post-abort lane cycles diverged");
        assert_eq!(f.attrs, seq.attrs, "post-abort lane attrs diverged");
        assert_eq!(f.sim, seq.sim, "post-abort lane metrics diverged");
    }
}

#[test]
fn prop_pooled_supersteps_equal_serial() {
    // 3 workers against K in {1, 2, 4} shards on purpose: worker count
    // not dividing the shard count exercises the work-stealing cursor
    let pool = WorkerPool::new(3);
    check("pooled_supersteps_equal_serial", 9, |rng| {
        let g = common::random_graph(&mut |n| rng.below(n), 24, 120);
        let cfg = ArchConfig::default();
        let k = [1usize, 2, 4][rng.below(3) as usize];
        let m = ShardedMachine::build(&g, k, &cfg, rng.next_u64());
        let w = Workload::ALL[rng.below(3) as usize];
        let src = rng.below(g.num_vertices() as u64) as u32;
        let opts = SimOptions::default();
        let ser = multichip::run(&m, w, src, &opts).map_err(|e| format!("serial: {e}"))?;
        let par = multichip::run_on(&m, w, src, &opts, Some(&pool))
            .map_err(|e| format!("pooled: {e}"))?;
        prop_assert!(
            ser.result.cycles == par.result.cycles,
            "K={} {} cycles {} != {}",
            k,
            w.name(),
            ser.result.cycles,
            par.result.cycles
        );
        prop_assert!(ser.result.attrs == par.result.attrs, "K={} {} attrs diverge", k, w.name());
        prop_assert!(ser.result.sim == par.result.sim, "K={} {} metrics diverge", k, w.name());
        prop_assert!(
            ser.shard_cycles == par.shard_cycles,
            "K={} {} shard busy cycles diverge",
            k,
            w.name()
        );
        prop_assert!(
            ser.supersteps == par.supersteps,
            "K={} {} supersteps {} != {}",
            k,
            w.name(),
            ser.supersteps,
            par.supersteps
        );
        Ok(())
    });
}

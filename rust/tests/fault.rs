//! Fault-injection differential battery (DESIGN.md §8).
//!
//! Five layers of guarantees, each checked bitwise where the design
//! promises bitwise:
//!
//! * a *quiet* active plan (seeded, rates 0.0) exercises the faulty
//!   code path — sequence numbers, checksums, the recovery bookkeeping —
//!   and must be indistinguishable from a no-injector run: same cycles,
//!   same attrs, same metrics, both new counters zero;
//! * *recoverable* faults (drops, corruptions, delays, transient stalls
//!   within budget) must reproduce the fault-free attrs, edge counts and
//!   per-shard metrics bit-exactly — only `link_retransmits`,
//!   `fault_recovery_cycles` and the lockstep cycle total may move;
//! * *unrecoverable* faults must surface the right [`SimError`] kind
//!   (`LinkFault` after the retransmit budget, `ChipFailed` wrapping a
//!   stall that exhausted its replays), and the machine must serve the
//!   next query as if nothing happened;
//! * the serving engine must retry transients up to the policy budget,
//!   abort on the deadline, and split a mixed batch into partial results;
//! * the batched performance paths (fused [`BatchInstance`] lanes,
//!   pooled lockstep supersteps) must preserve every guarantee above
//!   bitwise — the fault machinery cannot observe how work is scheduled.
//!
//! Randomized suites derive from one 64-bit seed; on failure the panic
//! names it. Re-run just that case with
//! `FLIP_FAULT_SEED=0x<seed> cargo test -q --test fault`.

mod common;

use flip::compiler::{compile, CompileOpts};
use flip::config::ArchConfig;
use flip::experiments::harness::{CompiledPair, ShardedPair};
use flip::graph::generate;
use flip::service::{Engine, Job, QueryErrorKind, ServePolicy};
use flip::sim::flip as flipsim;
use flip::sim::flip::SimOptions;
use flip::sim::multichip::{self, ShardedMachine};
use flip::sim::{BatchInstance, FaultPlan, SimError};
use flip::util::WorkerPool;
use flip::workloads::program::VertexProgram;
use flip::workloads::Workload;
use std::cell::Cell;

/// xorshift64* — the fuzz suite's generator, independent of the crate's
/// xoshiro so test inputs cannot covary with the fault plan's streams.
struct XorShift {
    s: u64,
}

impl XorShift {
    fn new(seed: u64) -> XorShift {
        XorShift { s: seed | 1 }
    }

    fn next_u64(&mut self) -> u64 {
        let mut x = self.s;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.s = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }

    fn chance(&mut self, p_percent: u64) -> bool {
        self.below(100) < p_percent
    }
}

/// The per-suite seed list: `cases` seeds derived from `salt`, or just
/// the user's `FLIP_FAULT_SEED` when set (the one-line repro path).
fn seeds(salt: u64, cases: usize) -> Vec<u64> {
    if let Ok(s) = std::env::var("FLIP_FAULT_SEED") {
        let s = s.trim();
        let parsed = match s.strip_prefix("0x") {
            Some(h) => u64::from_str_radix(h, 16),
            None => s.parse::<u64>(),
        };
        return vec![parsed.unwrap_or_else(|_| panic!("bad FLIP_FAULT_SEED `{s}`"))];
    }
    let mut x = XorShift::new(0xFA_17 ^ salt);
    (0..cases).map(|_| x.next_u64()).collect()
}

/// Run one randomized case, panicking with the repro seed on failure.
fn drive(name: &str, salt: u64, cases: usize, f: impl Fn(&mut XorShift) -> Result<(), String>) {
    for seed in seeds(salt, cases) {
        let mut x = XorShift::new(seed);
        if let Err(msg) = f(&mut x) {
            panic!(
                "fault battery `{name}` failed: {msg}\n  one-line repro: \
                 FLIP_FAULT_SEED={seed:#x} cargo test -q --test fault {name}"
            );
        }
    }
}

/// A seeded plan whose rates are zero: active machinery, zero injections.
fn quiet_plan(seed: u64) -> FaultPlan {
    FaultPlan::seeded(seed).with_link_rate(0.0).with_stall_rate(0.0)
}

// ---- 1. quiet active plan is bitwise inert ------------------------------

/// The fault handshake (sequence numbers, checksums, recovery counters)
/// must cost zero modeled cycles when no fault fires: for all seven
/// workloads at K ∈ {1, 2, 4}, a quiet active plan — and an unhit
/// deadline — produce runs bitwise identical to `SimOptions::default()`,
/// on both the sharded fabric and the single-chip event core.
#[test]
fn quiet_active_plan_is_bitwise_inert() {
    let mut x = XorShift::new(0x1AE7);
    let g = common::random_graph(&mut |n| x.below(n), 24, 48);
    let cfg = ArchConfig::default();
    let seed = 0xFA_B1_E5;
    let base = SimOptions::default();
    let quiet = SimOptions { faults: quiet_plan(0xD15EA5E), ..Default::default() };
    let far_deadline =
        SimOptions { deadline: Some(u64::MAX / 2), faults: quiet_plan(3), ..Default::default() };
    for (vp, view, src) in common::all_programs(&g, &mut |n| x.below(n)) {
        // single-chip event core
        let c = compile(&view, &cfg, &CompileOpts { seed, ..Default::default() });
        let r0 = flipsim::run_program(&c, &*vp, src, &base).expect("baseline single-chip run");
        for opts in [&quiet, &far_deadline] {
            let r = flipsim::run_program(&c, &*vp, src, opts).expect("quiet single-chip run");
            assert_eq!(r, r0, "single-chip quiet run diverged");
        }
        assert_eq!(r0.sim.link_retransmits, 0);
        assert_eq!(r0.sim.fault_recovery_cycles, 0);
        // sharded fabric at K ∈ {1, 2, 4}
        for k in [1usize, 2, 4] {
            let m = ShardedMachine::build(&view, k, &cfg, seed);
            let mut insts = m.new_instances();
            let s0 = multichip::run_program(&m, &mut insts, &*vp, src, &base)
                .expect("baseline sharded run");
            for opts in [&quiet, &far_deadline] {
                let mut insts = m.new_instances();
                let s = multichip::run_program(&m, &mut insts, &*vp, src, opts)
                    .expect("quiet sharded run");
                assert_eq!(s.result, s0.result, "K={k} quiet run diverged");
                assert_eq!(s.supersteps, s0.supersteps, "K={k} superstep count diverged");
            }
            assert_eq!(s0.result.sim.link_retransmits, 0, "K={k}");
            assert_eq!(s0.result.sim.fault_recovery_cycles, 0, "K={k}");
        }
    }
}

// ---- 2. recoverable faults reproduce fault-free results -----------------

/// Injected faults that stay within the retransmit/replay budgets must
/// not change *what* the fabric computes — attrs, traversed edges and
/// every metric except the recovery counters and the lockstep cycle
/// total are bit-identical to the fault-free run, and recovery only ever
/// makes the run slower.
#[test]
fn recoverable_faults_reproduce_fault_free_results() {
    // across the whole battery at these rates, faults must actually fire
    let fired = Cell::new(0u64);
    let unlucky = Cell::new(0u64);
    drive("recoverable_faults_reproduce_fault_free_results", 0x2EC, 4, |x| {
        let g = common::random_graph(&mut |n| x.below(n), 10, 48);
        let cfg = ArchConfig::default();
        let seed = x.next_u64();
        let k = if x.chance(50) { 2 } else { 4 };
        let plan = FaultPlan::seeded(x.next_u64())
            .with_link_rate(0.3)
            .with_stall_rate(0.1)
            .with_max_retransmits(16)
            .with_max_replays(6);
        let clean = SimOptions::default();
        let lossy = SimOptions { faults: plan, ..Default::default() };
        for (vp, view, src) in common::all_programs(&g, &mut |n| x.below(n)) {
            let m = ShardedMachine::build(&view, k, &cfg, seed);
            let mut insts = m.new_instances();
            let want = multichip::run_program(&m, &mut insts, &*vp, src, &clean)
                .map_err(|e| format!("fault-free run failed: {e}"))?;
            let mut insts = m.new_instances();
            let got = match multichip::run_program(&m, &mut insts, &*vp, src, &lossy) {
                Ok(r) => r,
                // an unlucky streak can exhaust even generous budgets
                // (~0.2^17 per packet); that is correct behavior, not a
                // reproduction failure — but it must stay rare
                Err(e) if e.is_retryable() => {
                    unlucky.set(unlucky.get() + 1);
                    continue;
                }
                Err(e) => return Err(format!("faulty run failed non-retryably: {e}")),
            };
            if got.result.attrs != want.result.attrs {
                return Err("attrs diverged under recoverable faults".into());
            }
            if got.result.edges_traversed != want.result.edges_traversed {
                return Err("edges_traversed diverged under recoverable faults".into());
            }
            if got.supersteps != want.supersteps {
                return Err("superstep count diverged under recoverable faults".into());
            }
            if got.result.cycles < want.result.cycles {
                return Err(format!(
                    "recovery made the run faster ({} < {})",
                    got.result.cycles, want.result.cycles
                ));
            }
            let recovered = got.result.sim.link_retransmits + got.result.sim.fault_recovery_cycles;
            fired.set(fired.get() + recovered);
            let mut sim = got.result.sim.clone();
            sim.link_retransmits = 0;
            sim.fault_recovery_cycles = 0;
            if sim != want.result.sim {
                return Err("metrics (beyond the recovery counters) diverged".into());
            }
        }
        Ok(())
    });
    assert!(fired.get() > 0, "the lossy battery never injected a single fault");
    assert!(unlucky.get() <= 2, "budget exhaustion should be rare at these rates");
}

// ---- 3. unrecoverable faults surface typed errors -----------------------

/// A link whose every transmission attempt faults exhausts the
/// retransmit budget and surfaces [`SimError::LinkFault`] with the
/// attempt count; the error is retryable and charges the cycles already
/// burned.
#[test]
fn exhausted_retransmits_surface_link_fault() {
    let mut x = XorShift::new(0x11FA);
    let g = common::random_graph(&mut |n| x.below(n), 32, 48);
    let cfg = ArchConfig::default();
    // WCC's dense seeding guarantees cut traffic on any 2-way partition
    let (vp, view, _src) = common::program_case(2, &g, &mut |n| x.below(n));
    let m = ShardedMachine::build(&view, 2, &cfg, 9);
    // every attempt faults; 1/3 of faults are delays (which deliver), so
    // scan a few plan seeds for one whose first drop/corrupt exhausts the
    // zero-retransmit budget — all-delay streams have probability ~3^-N
    let mut hit = None;
    for plan_seed in 1..=8u64 {
        let plan = FaultPlan::seeded(plan_seed)
            .with_link_rate(1.0)
            .with_stall_rate(0.0)
            .with_max_retransmits(0);
        let opts = SimOptions { faults: plan, ..Default::default() };
        let mut insts = m.new_instances();
        if let Err(e) = multichip::run_program(&m, &mut insts, &*vp, 0, &opts) {
            hit = Some(e);
            break;
        }
    }
    let err = hit.expect("a fully lossy link must eventually exhaust its budget");
    assert!(
        matches!(err, SimError::LinkFault { attempts: 1, .. }),
        "want LinkFault after 1 attempt, got {err:?}"
    );
    assert!(err.is_retryable());
    assert!(err.cycles_consumed() > 0, "the failed run burned modeled cycles");
    assert!(err.to_string().contains("undeliverable"), "{err}");
}

/// A chip that stalls on every replay exhausts the replay budget and
/// surfaces [`SimError::ChipFailed`] wrapping the watchdog diagnosis —
/// and the same machine instances serve the next (fault-free) query
/// bit-identically, proving the abort left no residue.
#[test]
fn exhausted_replays_surface_chip_failed_and_machine_recovers() {
    let mut x = XorShift::new(0x57A1);
    let g = common::random_graph(&mut |n| x.below(n), 24, 40);
    let cfg = ArchConfig::default();
    let (vp, view, src) = common::program_case(0, &g, &mut |n| x.below(n));
    let m = ShardedMachine::build(&view, 2, &cfg, 5);
    let mut insts = m.new_instances();
    let clean = SimOptions::default();
    let want = multichip::run_program(&m, &mut insts, &*vp, src, &clean).expect("baseline run");
    // p_stall = 1.0 stalls every replay deterministically
    let plan = FaultPlan::seeded(7).with_link_rate(0.0).with_stall_rate(1.0).with_max_replays(0);
    let opts = SimOptions { faults: plan, ..Default::default() };
    let err = multichip::run_program(&m, &mut insts, &*vp, src, &opts)
        .expect_err("an always-stalling chip must fail");
    assert!(matches!(err, SimError::ChipFailed { .. }), "{err:?}");
    assert!(err.is_retryable(), "a transient stall is retryable by contract");
    assert!(err.to_string().contains("shard"), "{err}");
    // the aborted instances hard-reset on their next run
    let again =
        multichip::run_program(&m, &mut insts, &*vp, src, &clean).expect("post-abort run");
    assert_eq!(again.result, want.result, "abort left residue in the machine");
}

// ---- 4. deadline-budgeted serving ---------------------------------------

/// The engine retries `Transient` failures exactly `max_retries` times
/// (reseeding the fault plan per attempt) and then reports the transient
/// error; a per-query deadline aborts with the `Deadline` kind, without
/// retrying; a mixed batch splits into partial results.
#[test]
fn engine_retries_transients_and_aborts_on_deadline() {
    let g = generate::road_network(40, 92, 100, 11);
    let cfg = ArchConfig::default();

    // always-stalling sharded fabric: every attempt fails retryably
    let spair = ShardedPair::build(&g, 2, &cfg, 11);
    let stall_always =
        FaultPlan::seeded(3).with_link_rate(0.0).with_stall_rate(1.0).with_max_replays(0);
    let mut engine = Engine::new_sharded(&spair)
        .with_workers(1)
        .with_opts(SimOptions { faults: stall_always, ..Default::default() })
        .with_policy(ServePolicy { deadline: None, max_retries: 2 });
    let rep = engine.serve(&[Job::Workload(Workload::Bfs, 0)]);
    assert_eq!(rep.retries, 2, "policy allows exactly 2 retries");
    assert_eq!(rep.deadline_aborts, 0);
    let err = rep.first_error().expect("an always-stalling fabric cannot answer");
    assert_eq!(err.kind, QueryErrorKind::Transient);
    assert!(err.is_retryable());

    // a 1-cycle deadline aborts any real query, and Deadline is final:
    // no retry is spent on it even though the policy would allow 3
    let pair = CompiledPair::build(&g, &cfg, 1);
    let mut engine = Engine::new(&pair)
        .with_workers(1)
        .with_policy(ServePolicy { deadline: Some(1), max_retries: 3 });
    let rep = engine.serve(&[Job::Workload(Workload::Bfs, 0)]);
    assert_eq!(rep.deadline_aborts, 1);
    assert_eq!(rep.retries, 0, "deadline exhaustion is not retryable");
    let err = rep.first_error().expect("a 1-cycle budget cannot answer");
    assert_eq!(err.kind, QueryErrorKind::Deadline);
    assert!(!err.is_retryable());
}

// ---- 5. fault machinery through the batched paths -----------------------

/// The quiet active plan must stay bitwise inert through fused
/// [`BatchInstance`] lanes too: every lane of a 3-lane batch running
/// under the quiet plan equals the plain sequential run of the same
/// query — the per-lane handshake state cannot leak across lanes.
#[test]
fn quiet_plan_is_inert_through_fused_lanes() {
    let mut x = XorShift::new(0xBA7C);
    let g = common::random_graph(&mut |n| x.below(n), 16, 40);
    let cfg = ArchConfig::default();
    let quiet = SimOptions { faults: quiet_plan(0xF00), ..Default::default() };
    for (vp, view, src) in common::all_programs(&g, &mut |n| x.below(n)) {
        let c = compile(&view, &cfg, &CompileOpts { seed: 7, ..Default::default() });
        let want =
            flipsim::run_program(&c, &*vp, src, &SimOptions::default()).expect("baseline run");
        let lanes = 3usize;
        let queries: Vec<(&dyn VertexProgram, u32)> =
            (0..lanes).map(|_| (vp.as_ref(), src)).collect();
        let mut batch = BatchInstance::new(&c, lanes);
        for (lane, r) in batch.run_batch(&c, &queries, &quiet).into_iter().enumerate() {
            let r = r.expect("quiet fused lane");
            assert_eq!(r, want, "{} lane {lane}: quiet fused run diverged", vp.name());
        }
    }
}

/// Pooled supersteps must stay bitwise identical to serial ones with
/// the fault machinery active: under a quiet plan the pooled run equals
/// the fault-free serial run outright; under a lossy-within-budget plan
/// the pooled run ≡ the serial lossy run bitwise, and versus the clean
/// run only the recovery counters and the cycle total may move.
#[test]
fn pooled_supersteps_stay_bitwise_under_faults() {
    drive("pooled_supersteps_stay_bitwise_under_faults", 0x9001, 3, |x| {
        let g = common::random_graph(&mut |n| x.below(n), 12, 40);
        let cfg = ArchConfig::default();
        let seed = x.next_u64();
        let k = if x.chance(50) { 2 } else { 4 };
        let pool = WorkerPool::new(k);
        let clean = SimOptions::default();
        let quiet = SimOptions { faults: quiet_plan(x.next_u64()), ..Default::default() };
        let lossy = SimOptions {
            faults: FaultPlan::seeded(x.next_u64())
                .with_link_rate(0.25)
                .with_stall_rate(0.05)
                .with_max_retransmits(16)
                .with_max_replays(6),
            ..Default::default()
        };
        for (vp, view, src) in common::all_programs(&g, &mut |n| x.below(n)) {
            let m = ShardedMachine::build(&view, k, &cfg, seed);
            let mut insts = m.new_instances();
            let want = multichip::run_program(&m, &mut insts, &*vp, src, &clean)
                .map_err(|e| format!("clean serial run: {e}"))?;
            let mut insts = m.new_instances();
            let q = multichip::run_program_on(&m, &mut insts, &*vp, src, &quiet, Some(&pool))
                .map_err(|e| format!("quiet pooled run: {e}"))?;
            if q.result != want.result || q.supersteps != want.supersteps {
                return Err(format!("{}: quiet pooled run diverged from clean serial", vp.name()));
            }
            let mut insts = m.new_instances();
            let ls = match multichip::run_program(&m, &mut insts, &*vp, src, &lossy) {
                Ok(r) => r,
                // rare budget exhaustion is legal; the pool contract is
                // vacuous for this case
                Err(e) if e.is_retryable() => continue,
                Err(e) => return Err(format!("lossy serial run failed non-retryably: {e}")),
            };
            let mut insts = m.new_instances();
            let lp = multichip::run_program_on(&m, &mut insts, &*vp, src, &lossy, Some(&pool))
                .map_err(|e| format!("lossy pooled run: {e}"))?;
            if lp.result != ls.result || lp.supersteps != ls.supersteps {
                return Err(format!("{}: pooled lossy run diverged from serial lossy", vp.name()));
            }
            if ls.result.attrs != want.result.attrs
                || ls.result.edges_traversed != want.result.edges_traversed
                || ls.supersteps != want.supersteps
            {
                return Err(format!("{}: recoverable faults changed the computation", vp.name()));
            }
            let mut sim = ls.result.sim.clone();
            sim.link_retransmits = 0;
            sim.fault_recovery_cycles = 0;
            if sim != want.result.sim {
                return Err(format!("{}: lossy run moved a non-recovery metric", vp.name()));
            }
        }
        Ok(())
    });
}

/// One rejected job (out-of-range source) must not poison the batch:
/// `partial()` splits it into the good answers and the one typed error.
#[test]
fn partial_results_split_a_mixed_batch() {
    let g = generate::road_network(32, 70, 80, 5);
    let pair = CompiledPair::build(&g, &ArchConfig::default(), 1);
    let mut engine = Engine::new(&pair).with_workers(2);
    let jobs = [
        Job::Workload(Workload::Bfs, 0),
        Job::Workload(Workload::Bfs, 10_000),
        Job::Workload(Workload::Sssp, 3),
    ];
    let rep = engine.serve(&jobs);
    let (ok, bad) = rep.partial();
    assert_eq!(ok.len(), 2, "both valid jobs answered");
    assert_eq!(bad.len(), 1);
    assert_eq!(bad[0].kind, QueryErrorKind::Rejected);
    assert_eq!(bad[0].cycles, 0, "a rejected job burned no budget");
    assert!(!bad[0].is_retryable(), "resubmitting bad input verbatim cannot help");
}

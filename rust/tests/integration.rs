//! Integration tests: full pipeline (datasets → compiler → cycle-accurate
//! simulator → references) across modules, plus the baselines and the
//! dynamic-attribute path.

use flip::compiler::{compile, tablegen, CompileOpts};
use flip::config::{ArchConfig, McuConfig};
use flip::experiments::harness::{self, Baselines, CompiledPair, ExpEnv};
use flip::graph::datasets::{self, Group};
use flip::graph::{generate, reference, Graph};
use flip::sim::flip::{self as flipsim, SimOptions};
use flip::workloads::program::VertexProgram;
use flip::workloads::{mis, navigation, pagerank, Workload};

fn quick_env() -> ExpEnv {
    let mut env = ExpEnv::quick();
    env.graphs_per_group = 2;
    env.sources_per_graph = 2;
    env
}

#[test]
fn every_group_and_workload_validates() {
    let env = quick_env();
    for group in Group::ON_CHIP {
        let graphs = env.graphs(group);
        for (gi, g) in graphs.iter().enumerate() {
            let pair = CompiledPair::build(g, &env.cfg, env.seed);
            for w in Workload::ALL {
                for src in env.sources(group, g, gi) {
                    let r = harness::run_flip(&pair, w, src);
                    let view = if w.needs_undirected() { &pair.wcc_view } else { &pair.graph };
                    assert_eq!(
                        r.attrs,
                        w.reference(view, src),
                        "{} {} graph {gi} src {src}",
                        group.name(),
                        w.name()
                    );
                    assert!(r.cycles > 0);
                }
            }
        }
    }
}

#[test]
fn all_three_architectures_agree() {
    let env = quick_env();
    let base = Baselines::build(&env.cfg, &env.mcu, env.seed);
    let g = datasets::generate_one(Group::Srn, 1, env.seed);
    let pair = CompiledPair::build(&g, &env.cfg, env.seed);
    for w in Workload::ALL {
        let f = harness::run_flip(&pair, w, 3);
        assert_eq!(f.attrs, base.run_cgra(w, &g, 3).attrs, "{} cgra", w.name());
        assert_eq!(f.attrs, base.run_mcu(w, &g, 3).attrs, "{} mcu", w.name());
    }
}

#[test]
fn swap_path_end_to_end() {
    // 3 copies: vertices spread over three array replicas
    let g = generate::road_network(700, 1600, 2000, 3);
    let cfg = ArchConfig::default();
    let c = compile(&g, &cfg, &CompileOpts::default());
    assert_eq!(c.placement.num_copies, 3);
    let opts = SimOptions { max_cycles: 1_000_000_000, watchdog: 5_000_000, ..Default::default() };
    let r = flipsim::run(&c, Workload::Bfs, 0, &opts).unwrap();
    assert_eq!(r.attrs, reference::bfs_levels(&g, 0));
    assert!(r.sim.swaps > 0);
    assert!(r.sim.swap_cycles > 0);
}

#[test]
fn dynamic_weight_update_path() {
    let g = generate::road_network(96, 219, 249, 5);
    let cfg = ArchConfig::default();
    let mut c = compile(&g, &cfg, &CompileOpts::default());
    let r1 = flipsim::run(&c, Workload::Sssp, 0, &SimOptions::default()).unwrap();
    assert_eq!(r1.attrs, reference::dijkstra(&g, 0));
    // re-weight every edge to 1: SSSP becomes BFS levels
    let edges: Vec<(u32, u32, u32)> =
        g.arcs().filter(|&(u, v, _)| u < v).map(|(u, v, _)| (u, v, 1)).collect();
    let g_unit = Graph::from_edges(g.num_vertices(), &edges, false);
    tablegen::update_edge_weights(&mut c, &g_unit);
    let r2 = flipsim::run(&c, Workload::Sssp, 0, &SimOptions::default()).unwrap();
    assert_eq!(r2.attrs, reference::bfs_levels(&g, 0));
}

#[test]
fn mode_switching_same_fabric() {
    // op-centric and data-centric produce identical results on one config
    let g = datasets::generate_one(Group::Srn, 0, 1);
    let cfg = ArchConfig::default();
    let c = compile(&g, &cfg, &CompileOpts::default());
    let data = flipsim::run(&c, Workload::Bfs, 0, &SimOptions::default()).unwrap();
    let k = flip::sim::opcentric::compile_kernel(Workload::Bfs, &cfg, 1, 1).unwrap();
    let op = flip::sim::opcentric::run(&k, &g, 0);
    assert_eq!(data.attrs, op.attrs);
    // and the data-centric mode is substantially faster (the paper's point)
    assert!(op.cycles > 5 * data.cycles, "op {} vs data {}", op.cycles, data.cycles);
}

#[test]
fn scaled_arrays_stay_correct() {
    // 4x4 and 12x12 arrays (Fig 12 sizes) remain functionally exact
    for k in [4usize, 12] {
        let cfg = ArchConfig::scaled(k);
        let g = datasets::road_for_capacity(cfg.capacity(), 0, 9);
        let c = compile(&g, &cfg, &CompileOpts::default());
        let r = flipsim::run(&c, Workload::Wcc, 0, &SimOptions::default()).unwrap();
        assert_eq!(r.attrs, reference::wcc_labels(&g), "array {k}x{k}");
    }
}

#[test]
fn tree_workloads_from_root() {
    let g = datasets::generate_one(Group::Tree, 3, 7);
    let pair = CompiledPair::build(&g, &ArchConfig::default(), 7);
    for w in Workload::ALL {
        let r = harness::run_flip(&pair, w, 0);
        let view = if w.needs_undirected() { &pair.wcc_view } else { &pair.graph };
        assert_eq!(r.attrs, w.reference(view, 0), "{}", w.name());
    }
}

#[test]
fn mcu_slower_but_correct_and_heap_beats_cgra_sssp() {
    let env = quick_env();
    let base = Baselines::build(&env.cfg, &env.mcu, env.seed);
    let g = datasets::generate_one(Group::Lrn, 0, env.seed);
    let m = base.run_mcu(Workload::Sssp, &g, 0);
    let c = base.run_cgra(Workload::Sssp, &g, 0);
    assert_eq!(m.attrs, reference::dijkstra(&g, 0));
    // paper: MCU performs better than classic CGRA on SSSP (heap vs O(V^2))
    let m_s = harness::seconds(m.cycles, env.mcu.freq_mhz);
    let c_s = harness::seconds(c.cycles, env.cfg.freq_mhz);
    assert!(m_s < c_s, "MCU {m_s}s vs CGRA {c_s}s");
}

#[test]
fn energy_model_orders_architectures_as_paper() {
    let env = quick_env();
    let emodel = harness::calibrated_energy(&env);
    let base = Baselines::build(&env.cfg, &env.mcu, env.seed);
    let g = datasets::generate_one(Group::Lrn, 0, env.seed);
    let pair = CompiledPair::build(&g, &env.cfg, env.seed);
    let f = harness::run_flip(&pair, Workload::Bfs, 0);
    let c = base.run_cgra(Workload::Bfs, &g, 0);
    let e_flip = emodel.run_energy_uj(&f.sim.activity, f.cycles);
    let e_cgra =
        flip::energy::baseline_energy_uj(flip::energy::CGRA_POWER_MW, c.cycles, env.cfg.freq_mhz);
    // paper Fig 10b: FLIP needs 3-15% of classic CGRA energy
    assert!(e_flip < 0.5 * e_cgra, "FLIP {e_flip} µJ vs CGRA {e_cgra} µJ");
}

#[test]
fn pagerank_rounds_match_oracle_on_datasets() {
    // the full host-driven loop over the fabric reproduces the integer
    // fixed-point oracle bit-for-bit, and lands near float PageRank
    let env = quick_env();
    for group in [Group::Lrn, Group::Syn] {
        let g = datasets::generate_one(group, 0, env.seed);
        let c = compile(&g, &env.cfg, &CompileOpts { seed: env.seed, ..Default::default() });
        let run = pagerank::run_rounds(&c, &g, 10, &SimOptions::default()).unwrap();
        assert_eq!(run.ranks, reference::pagerank(&g, 10), "{}", group.name());
        let float = reference::pagerank_f64(&g, 10);
        for v in 0..g.num_vertices() {
            let got = run.ranks[v] as f64 / reference::PR_SCALE as f64;
            assert!(
                (got - float[v]).abs() < 2e-3,
                "{} v{v}: fixed {got} vs float {}",
                group.name(),
                float[v]
            );
        }
    }
}

#[test]
fn astar_navigation_matches_reference_on_road_networks() {
    let env = quick_env();
    let g = datasets::generate_one(Group::Lrn, 1, env.seed);
    let c = compile(&g, &env.cfg, &CompileOpts { seed: env.seed, ..Default::default() });
    let lm = navigation::Landmarks::build(&g, 4);
    let exact_from_7 = reference::dijkstra(&g, 7);
    for target in [13u32, 101, 250] {
        let p = navigation::plan(&c, &lm, 7, target, &SimOptions::default()).unwrap();
        assert_eq!(p.distance, exact_from_7[target as usize], "7->{target}");
        // simulated attrs equal the bounded-relaxation oracle exactly
        let vp = lm.query(7, target);
        let r = flipsim::run_program(&c, &vp, 7, &SimOptions::default()).unwrap();
        assert_eq!(r.attrs, vp.reference(&g, 7));
    }
}

#[test]
fn mis_matches_reference_on_datasets() {
    let env = quick_env();
    for group in [Group::Srn, Group::Syn] {
        let g = datasets::generate_one(group, 0, env.seed);
        let (m, view) = mis::Mis::build(&g, 0x9115 ^ env.seed);
        let c = compile(&view, &env.cfg, &CompileOpts { seed: env.seed, ..Default::default() });
        let r = mis::run(&c, &m, &SimOptions::default()).unwrap();
        assert_eq!(r.attrs, reference::greedy_mis(&view, &m.prio), "{}", group.name());
        assert!(mis::is_independent(&view, &r.attrs));
        assert!(mis::is_maximal(&view, &r.attrs));
    }
}

#[test]
fn extended_workloads_swap_path_end_to_end() {
    // > 256 vertices forces 2 array copies: dense seeding + parked
    // packets + slice swaps, for a stateful extended program
    let g = generate::road_network(300, 690, 800, 41);
    let cfg = ArchConfig::default();
    let c = compile(&g, &cfg, &CompileOpts::default());
    let opts = SimOptions { max_cycles: 1_000_000_000, watchdog: 5_000_000, ..Default::default() };
    let run = pagerank::run_rounds(&c, &g, 3, &opts).unwrap();
    assert_eq!(run.ranks, reference::pagerank(&g, 3), "PageRank under swapping");

    let (m, view) = mis::Mis::build(&g, 99);
    let cv = compile(&view, &cfg, &CompileOpts::default());
    let r = mis::run(&cv, &m, &opts).unwrap();
    assert_eq!(r.attrs, reference::greedy_mis(&view, &m.prio), "MIS under swapping");
    assert!(r.sim.swaps > 0, "dominance view must span copies");
}

#[test]
fn watchdog_reports_instead_of_hanging() {
    let g = generate::synthetic(32, 64, 1);
    let cfg = ArchConfig::default();
    let c = compile(&g, &cfg, &CompileOpts::default());
    // absurdly small max_cycles triggers the safety net, not a hang
    let opts = SimOptions { max_cycles: 2, ..Default::default() };
    let err = flipsim::run(&c, Workload::Bfs, 0, &opts).unwrap_err();
    assert!(matches!(err, flip::sim::SimError::MaxCycles { limit: 2 }), "{err:?}");
    assert!(err.to_string().contains("max_cycles"));
}

#[test]
fn mcu_config_variation_scales_cycles() {
    let g = datasets::generate_one(Group::Srn, 0, 1);
    let fast = McuConfig { t_fetch: 0, ..Default::default() };
    let slow = McuConfig { t_fetch: 3, ..Default::default() };
    let rf = flip::sim::mcu::run(Workload::Bfs, &g, 0, &fast);
    let rs = flip::sim::mcu::run(Workload::Bfs, &g, 0, &slow);
    assert_eq!(rf.attrs, rs.attrs);
    assert!(rs.cycles > 2 * rf.cycles);
}

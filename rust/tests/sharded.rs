//! The sharding differential battery (DESIGN.md §7): K-chip lockstep
//! runs must converge to vertex attributes equal to the single-chip
//! event core AND the CPU oracle, for all seven workloads, for
//! K ∈ {1, 2, 4} — with K = 1 additionally bit-identical in cycles and
//! every metric to an unsharded run. Swapping shards and aborted runs
//! are part of the battery.

mod common;

use flip::compiler::{compile, CompileOpts};
use flip::config::ArchConfig;
use flip::graph::{partition, reference, Graph};
use flip::prop_assert;
use flip::sim::flip as flipsim;
use flip::sim::flip::SimOptions;
use flip::sim::multichip::{self, ShardedMachine};
use flip::util::{proptest::check, Rng};
use flip::workloads::program::VertexProgram;
use flip::workloads::Workload;

/// Random connected weighted undirected graph (shared builder, drawing
/// from this suite's xoshiro stream).
fn random_graph(rng: &mut Rng, lo: usize, hi: usize) -> Graph {
    common::random_graph(&mut |n| rng.below(n), lo, hi)
}

/// All seven workload programs for one (undirected) graph.
fn all_programs(rng: &mut Rng, g: &Graph) -> Vec<common::ProgramCase> {
    common::all_programs(g, &mut |n| rng.below(n))
}

#[test]
fn prop_sharded_equals_single_chip_and_oracle_all_workloads() {
    // the headline invariant: K-shard attrs == single-chip event-core
    // attrs == CPU oracle for every workload, K ∈ {1, 2, 4}; K = 1 is
    // additionally metric-identical to the unsharded machine
    check("sharded_all_workloads", 5, |rng| {
        let g = random_graph(rng, 12, 72);
        let seed = rng.next_u64();
        let cfg = ArchConfig::default();
        let opts = SimOptions::default();
        for (vp, view, src) in all_programs(rng, &g) {
            let c = compile(&view, &cfg, &CompileOpts { seed, ..Default::default() });
            let single = flipsim::run_program(&c, vp.as_ref(), src, &opts)
                .map_err(|e| format!("single-chip {}: {e}", vp.name()))?;
            let want = vp.reference(&view, src);
            prop_assert!(
                single.attrs == want,
                "{}: single-chip oracle mismatch (|V|={})",
                vp.name(),
                view.num_vertices()
            );
            for k in [1usize, 2, 4] {
                let m = ShardedMachine::build(&view, k, &cfg, seed);
                let mut insts = m.new_instances();
                let r = multichip::run_program(&m, &mut insts, vp.as_ref(), src, &opts)
                    .map_err(|e| format!("{} K={k}: {e}", vp.name()))?;
                prop_assert!(
                    r.result.attrs == want,
                    "{} K={k}: sharded attrs diverge from oracle (|V|={})",
                    vp.name(),
                    view.num_vertices()
                );
                if k == 1 {
                    prop_assert!(
                        r.result.cycles == single.cycles,
                        "{} K=1: cycles {} != {}",
                        vp.name(),
                        r.result.cycles,
                        single.cycles
                    );
                    prop_assert!(
                        r.result.edges_traversed == single.edges_traversed,
                        "{} K=1: edges diverge",
                        vp.name()
                    );
                    prop_assert!(
                        r.result.sim == single.sim,
                        "{} K=1: metrics diverge",
                        vp.name()
                    );
                    prop_assert!(r.supersteps == 1, "K=1 must finish in one superstep");
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sharded_with_intra_shard_swapping_matches_oracle() {
    // shards bigger than one array copy: the per-chip swap engine runs
    // inside the lockstep loop
    check("sharded_swapping", 3, |rng| {
        let g = random_graph(rng, 540, 650);
        let cfg = ArchConfig::default();
        let m = ShardedMachine::build(&g, 2, &cfg, rng.next_u64());
        prop_assert!(
            m.shards.iter().any(|c| c.placement.num_copies >= 2),
            "expected at least one multi-copy shard (|V|={})",
            g.num_vertices()
        );
        let opts =
            SimOptions { max_cycles: 1_000_000_000, watchdog: 5_000_000, ..Default::default() };
        let r = multichip::run(&m, Workload::Bfs, 0, &opts).map_err(|e| e.to_string())?;
        prop_assert!(r.result.sim.swaps > 0, "expected intra-shard data swapping");
        prop_assert!(
            r.result.attrs == reference::bfs_levels(&g, 0),
            "BFS mismatch under sharding + swapping"
        );
        Ok(())
    });
}

#[test]
fn prop_sharded_engine_matches_single_engine() {
    // the serving layer on top: one sharded engine and one single-chip
    // engine answer the same mixed batch with identical attributes and
    // navigation distances
    check("sharded_engine", 4, |rng| {
        use flip::experiments::harness::{CompiledPair, ShardedPair};
        use flip::service::{Engine, Job};
        let g = random_graph(rng, 16, 64);
        let seed = rng.next_u64();
        let cfg = ArchConfig::default();
        let n = g.num_vertices() as u64;
        let jobs: Vec<Job> = (0..6)
            .map(|i| {
                let s = rng.below(n) as u32;
                let t = rng.below(n) as u32;
                match i % 3 {
                    0 => Job::Workload(Workload::Bfs, s),
                    1 => Job::Workload(Workload::Wcc, s),
                    _ => Job::Navigate { source: s, target: t },
                }
            })
            .collect();
        let pair = CompiledPair::build(&g, &cfg, seed);
        let spair = ShardedPair::build(&g, 2, &cfg, seed);
        let mut single = Engine::new(&pair).with_workers(2).with_navigation(3);
        let mut sharded = Engine::new_sharded(&spair).with_workers(2).with_navigation(3);
        let a = single.serve(&jobs);
        let b = sharded.serve(&jobs);
        for (i, (ra, rb)) in a.results.iter().zip(&b.results).enumerate() {
            let (qa, qb) = match (ra, rb) {
                (Ok(qa), Ok(qb)) => (qa, qb),
                _ => return Err(format!("job {i}: unexpected failure {ra:?} / {rb:?}")),
            };
            prop_assert!(qa.run.attrs == qb.run.attrs, "job {i}: attrs diverge");
            prop_assert!(qa.distance == qb.distance, "job {i}: distance diverges");
        }
        Ok(())
    });
}

#[test]
fn prop_sharded_mono_path_equals_dyn_shim() {
    // the monomorphization differential on the multi-chip layer: the
    // with_builtin (concrete-P) lockstep run must be bit-identical —
    // cycles, attrs, metrics, superstep count — to the dyn-shim run, for
    // K ∈ {1, 2, 4}
    check("sharded_mono_equals_dyn", 4, |rng| {
        let g = random_graph(rng, 12, 72);
        let seed = rng.next_u64();
        let cfg = ArchConfig::default();
        let opts = SimOptions::default();
        let src = rng.below(g.num_vertices() as u64) as u32;
        for k in [1usize, 2, 4] {
            let m = ShardedMachine::build(&g, k, &cfg, seed);
            // multichip::run dispatches through with_builtin (mono path)
            let mono = multichip::run(&m, Workload::Sssp, src, &opts)
                .map_err(|e| format!("mono K={k}: {e}"))?;
            let vp = Workload::Sssp.builtin_program();
            let mut insts = m.new_instances();
            let shim = multichip::run_program(&m, &mut insts, vp.as_ref(), src, &opts)
                .map_err(|e| format!("dyn K={k}: {e}"))?;
            prop_assert!(
                mono.result.cycles == shim.result.cycles,
                "K={k}: cycles {} != {}",
                mono.result.cycles,
                shim.result.cycles
            );
            prop_assert!(mono.result.attrs == shim.result.attrs, "K={k}: attrs diverge");
            prop_assert!(mono.result.sim == shim.result.sim, "K={k}: metrics diverge");
            prop_assert!(mono.supersteps == shim.supersteps, "K={k}: supersteps diverge");
        }
        Ok(())
    });
}

#[test]
fn sharded_abort_surfaces_as_error_and_instances_recover() {
    // part of the battery: a watchdog/max-cycles abort inside one shard
    // is an Err value, and the same instances then serve correct results
    let mut rng = Rng::new(0x5AAB);
    let g = random_graph(&mut rng, 48, 64);
    let cfg = ArchConfig::default();
    let m = ShardedMachine::build(&g, 4, &cfg, 7);
    let mut insts = m.new_instances();
    let vp = Workload::Sssp.builtin_program();
    let tiny = SimOptions { max_cycles: 1, ..Default::default() };
    assert!(multichip::run_program(&m, &mut insts, vp.as_ref(), 0, &tiny).is_err());
    let r = multichip::run_program(&m, &mut insts, vp.as_ref(), 0, &SimOptions::default())
        .unwrap();
    assert_eq!(r.result.attrs, reference::dijkstra(&g, 0));
}

#[test]
fn partition_validates_on_random_graphs() {
    check("partition_valid", 20, |rng| {
        let g = random_graph(rng, 8, 120);
        for k in [1usize, 2, 3, 4, 7] {
            let p = partition::partition(&g, k);
            p.validate(&g).map_err(|e| format!("k={k}: {e}"))?;
        }
        Ok(())
    });
}

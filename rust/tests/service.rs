//! Query-serving layer tests (DESIGN.md §6): the engine must be a pure
//! throughput optimization — bit-identical to sequential single-query
//! runs — and must surface failures as data instead of thread panics.

use flip::experiments::harness::{self, CompiledPair, ExpEnv};
use flip::graph::datasets::{self, Group};
use flip::graph::{generate, reference, Delta};
use flip::service::{Engine, Job};
use flip::sim::flip::SimOptions;
use flip::workloads::{navigation, Workload};

#[test]
fn engine_matches_sequential_run_flip() {
    let env = ExpEnv::quick();
    let g = datasets::generate_one(Group::Srn, 0, env.seed);
    let pair = CompiledPair::build(&g, &env.cfg, env.seed);
    let trio = [
        (Workload::Bfs, 0u32),
        (Workload::Sssp, 3),
        (Workload::Wcc, 0),
        (Workload::Bfs, 5),
        (Workload::Sssp, 9),
        (Workload::Wcc, 2),
    ];
    let jobs: Vec<Job> = trio.iter().map(|&(w, s)| Job::Workload(w, s)).collect();
    let mut engine = Engine::new(&pair).with_workers(4);
    let rep = engine.serve(&jobs);
    assert_eq!(rep.results.len(), jobs.len());
    for (r, &(w, s)) in rep.results.iter().zip(&trio) {
        let q = r.as_ref().expect("query failed");
        let seq = harness::run_flip(&pair, w, s);
        assert_eq!(q.run.cycles, seq.cycles, "{} src {s}: cycles", w.name());
        assert_eq!(q.run.attrs, seq.attrs, "{} src {s}: attrs", w.name());
        assert_eq!(q.run.edges_traversed, seq.edges_traversed);
        assert_eq!(q.run.sim, seq.sim, "{} src {s}: metrics", w.name());
    }
}

#[test]
fn engine_is_deterministic_across_worker_counts() {
    let env = ExpEnv::quick();
    let g = datasets::generate_one(Group::Srn, 1, env.seed);
    let pair = CompiledPair::build(&g, &env.cfg, env.seed);
    let jobs: Vec<Job> = (0..12)
        .map(|i| Job::Workload([Workload::Bfs, Workload::Sssp][i % 2], (i * 3) as u32))
        .collect();
    let mut seq = Engine::new(&pair).with_workers(1);
    let mut par = Engine::new(&pair).with_workers(8);
    let a = seq.serve(&jobs);
    let b = par.serve(&jobs);
    for (x, y) in a.results.iter().zip(&b.results) {
        let (x, y) = (x.as_ref().unwrap(), y.as_ref().unwrap());
        assert_eq!(x.run.cycles, y.run.cycles);
        assert_eq!(x.run.attrs, y.run.attrs);
        assert_eq!(x.run.sim, y.run.sim);
    }
}

#[test]
fn engine_serves_navigation_exactly() {
    let g = generate::road_network(96, 219, 249, 17);
    let cfg = flip::config::ArchConfig::default();
    let pair = CompiledPair::build(&g, &cfg, 17);
    let endpoints = [(0u32, 90u32), (5, 60), (33, 2), (7, 7)];
    let queries = endpoints.map(|(s, t)| Job::Navigate { source: s, target: t });
    let mut engine = Engine::new(&pair).with_workers(3).with_navigation(4);
    let rep = engine.serve(&queries);
    // the engine's landmark setup mirrors navigation::plan exactly
    let lm = navigation::Landmarks::build(&g, 4);
    for (r, &(s, t)) in rep.results.iter().zip(&endpoints) {
        let q = r.as_ref().expect("navigation query failed");
        let want = reference::dijkstra(&g, s)[t as usize];
        assert_eq!(q.distance, Some(want), "wrong distance {s} -> {t}");
        let p = navigation::plan(&pair.directed, &lm, s, t, &SimOptions::default()).unwrap();
        assert_eq!(q.run.cycles, p.run.cycles, "engine route {s}->{t} diverged from plan()");
        assert_eq!(q.run.attrs, p.run.attrs);
    }
}

#[test]
fn navigation_on_directed_graph_is_an_error() {
    let g = generate::synthetic(48, 96, 7); // directed
    assert!(g.is_directed());
    let pair = CompiledPair::build(&g, &flip::config::ArchConfig::default(), 7);
    let mut engine = Engine::new(&pair).with_workers(2);
    let rep = engine.serve(&[Job::Navigate { source: 0, target: 5 }]);
    let err = rep.results[0].as_ref().unwrap_err();
    assert!(err.msg.contains("undirected"), "{err}");
}

#[test]
fn engine_surfaces_sim_aborts_without_poisoning_the_batch() {
    let env = ExpEnv::quick();
    let g = datasets::generate_one(Group::Srn, 0, env.seed);
    let pair = CompiledPair::build(&g, &env.cfg, env.seed);
    // every run aborts at cycle 1 — the batch still completes in order,
    // with one QueryError value per job (no worker panic, no early exit)
    let tiny = SimOptions { max_cycles: 1, ..Default::default() };
    let jobs: Vec<Job> = (0..6).map(|i| Job::Workload(Workload::Bfs, i as u32)).collect();
    let mut engine = Engine::new(&pair).with_workers(3).with_opts(tiny);
    let rep = engine.serve(&jobs);
    assert_eq!(rep.results.len(), 6);
    for r in &rep.results {
        let e = r.as_ref().unwrap_err();
        assert!(e.msg.contains("max_cycles"), "{e}");
    }
    // and the same engine recovers for a normal batch (hard reset path)
    let mut ok_engine = Engine::new(&pair).with_workers(3);
    let rep2 = ok_engine.serve(&jobs);
    assert!(rep2.first_error().is_none());
}

#[test]
fn engine_reports_throughput() {
    let env = ExpEnv::quick();
    let g = datasets::generate_one(Group::Srn, 2, env.seed);
    let pair = CompiledPair::build(&g, &env.cfg, env.seed);
    let jobs: Vec<Job> = (0..8).map(|i| Job::Workload(Workload::Bfs, i as u32)).collect();
    let mut engine = Engine::new(&pair);
    let rep = engine.serve(&jobs);
    assert!(rep.first_error().is_none());
    assert!(rep.workers >= 1 && rep.workers <= jobs.len());
    assert!(rep.wall_seconds > 0.0);
    assert!(rep.queries_per_s > 0.0);
    assert!(rep.sim_cycles > 0);
    assert!(rep.pe_cycles_per_s > 0.0);
}

#[test]
fn attr_updates_flow_through_the_engine() {
    // compile once, serve, patch weights in place, serve again: the
    // second batch must answer against the *new* costs exactly
    let g = generate::road_network(64, 146, 166, 23);
    let cfg = flip::config::ArchConfig::default();
    let mut pair = CompiledPair::build(&g, &cfg, 23);
    let jobs = [Job::Workload(Workload::Sssp, 4)];
    let before = Engine::new(&pair).with_workers(1).serve(&jobs);
    assert_eq!(
        before.results[0].as_ref().unwrap().run.attrs,
        reference::dijkstra(&g, 4)
    );
    // double the weight of every edge touching vertex 4's neighborhood
    let changes: Vec<(u32, u32, u32)> =
        g.arcs().filter(|&(u, v, _)| u < v && u < 8).map(|(u, v, w)| (u, v, w * 2)).collect();
    assert!(!changes.is_empty());
    let mut g2 = g.clone();
    let delta = Delta::from_edges(&g, &changes);
    pair.apply_attr_updates(&delta).unwrap();
    g2.apply_delta(&delta).unwrap();
    let after = Engine::new(&pair).with_workers(1).serve(&jobs);
    assert_eq!(
        after.results[0].as_ref().unwrap().run.attrs,
        reference::dijkstra(&g2, 4),
        "patched tables must answer against the new weights"
    );
}

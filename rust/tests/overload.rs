//! Overload-resilience battery (DESIGN.md §11): the degradation ladder
//! under deterministic chaos.
//!
//! The ladder — adaptive admission, CoDel-style shedding, per-target
//! circuit breakers, degraded answers, seeded host chaos — promises:
//!
//! * **inertness**: with [`ChaosPlan::none`] and the breaker merely
//!   *enabled*, a server is bitwise identical ticket-for-ticket to one
//!   with the whole ladder disabled, for single-chip and sharded
//!   targets alike — resilience machinery costs nothing until it fires;
//! * **selective shedding**: under seeded overload only best-effort
//!   tickets are dropped, every drop is a typed
//!   [`QueryErrorKind::Shed`] outcome (never silence), interactive
//!   queries all complete within the deadline budget, and the ticket
//!   ledger conserves: `submitted = served + failed + shed + rejected`;
//! * **breaker + stale reads**: consecutive injected fatals trip the
//!   (class, target) slot; while open, answers degrade to the newest
//!   healthy epoch and are bitwise what a batch engine computes over a
//!   recompile of that epoch; a scheduled probe under restored health
//!   closes the slot and exact serving resumes;
//! * **panic isolation**: an injected worker panic fails exactly its
//!   ticket (typed `Fatal`, counted) and the server keeps serving.
//!
//! Randomized suites derive from one 64-bit seed; on failure the panic
//! names it. Re-run just that case with
//! `FLIP_CHAOS_SEED=0x<seed> cargo test -q --test overload`.

mod common;

use flip::config::ArchConfig;
use flip::experiments::harness::{CompiledPair, ShardedPair};
use flip::graph::embed::Embeddings;
use flip::graph::{Delta, Graph};
use flip::service::breaker::{BreakerConfig, BreakerState, JobClass};
use flip::service::chaos::ChaosPlan;
use flip::service::stream::{
    AdmissionError, Degraded, EpochStore, Priority, StreamConfig, StreamOutcome, StreamServer,
};
use flip::service::{Engine, Job, QueryErrorKind, ServePolicy};
use flip::workloads::ann::{AnnIndex, AnnParams};
use flip::workloads::Workload;
use std::sync::Arc;

/// xorshift64* — the battery's generator, independent of the crate's
/// xoshiro so test inputs cannot covary with compile-time streams.
struct XorShift {
    s: u64,
}

impl XorShift {
    fn new(seed: u64) -> XorShift {
        XorShift { s: seed | 1 }
    }

    fn next_u64(&mut self) -> u64 {
        let mut x = self.s;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.s = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n
    }
}

/// The per-suite seed list: `cases` seeds derived from `salt`, or just
/// the user's `FLIP_CHAOS_SEED` when set (the one-line repro path).
fn seeds(salt: u64, cases: usize) -> Vec<u64> {
    if let Ok(s) = std::env::var("FLIP_CHAOS_SEED") {
        let s = s.trim();
        let parsed = match s.strip_prefix("0x") {
            Some(h) => u64::from_str_radix(h, 16),
            None => s.parse::<u64>(),
        };
        return vec![parsed.unwrap_or_else(|_| panic!("bad FLIP_CHAOS_SEED `{s}`"))];
    }
    let mut x = XorShift::new(0xC4A0_5 ^ salt);
    (0..cases).map(|_| x.next_u64()).collect()
}

/// Run one randomized case, panicking with the repro seed on failure.
fn drive(name: &str, salt: u64, cases: usize, f: impl Fn(&mut XorShift) -> Result<(), String>) {
    for seed in seeds(salt, cases) {
        let mut x = XorShift::new(seed);
        if let Err(msg) = f(&mut x) {
            panic!(
                "overload battery `{name}` failed: {msg}\n  one-line repro: \
                 FLIP_CHAOS_SEED={seed:#x} cargo test -q --test overload {name}"
            );
        }
    }
}

/// A weight-only delta reweighting one random existing arc of `g`.
fn random_weight_delta(g: &Graph, x: &mut XorShift) -> Delta {
    let arcs: Vec<(u32, u32, u32)> = g.arcs().collect();
    let (u, v, _) = arcs[x.below(arcs.len() as u64) as usize];
    Delta::from_edges(g, &[(u, v, 1 + x.below(99) as u32)])
}

/// Modeled cycles one job costs on this pair, via the batch engine (the
/// streaming layer's bitwise oracle).
fn measured_cycles(pair: &CompiledPair, job: Job) -> Result<u64, String> {
    let rep = Engine::new(pair).with_workers(1).serve(&[job]);
    match &rep.results[0] {
        Ok(q) => Ok(q.run.cycles),
        Err(e) => Err(format!("capacity probe failed: {e}")),
    }
}

/// Full-fidelity outcome equality: identity, routing metadata, and the
/// bitwise answer (or the typed error, message included).
fn same_outcome(a: &StreamOutcome, b: &StreamOutcome) -> bool {
    if a.id != b.id
        || a.job != b.job
        || a.epoch != b.epoch
        || a.shared != b.shared
        || a.lag != b.lag
        || a.priority != b.priority
        || a.degraded != b.degraded
    {
        return false;
    }
    match (&a.result, &b.result) {
        (Ok(p), Ok(q)) => {
            p.run.cycles == q.run.cycles
                && p.run.attrs == q.run.attrs
                && p.run.sim == q.run.sim
                && p.distance == q.distance
                && p.neighbors == q.neighbors
        }
        (Err(p), Err(q)) => p.kind == q.kind && p.cycles == q.cycles && p.msg == q.msg,
        _ => false,
    }
}

// ---- 1. the idle ladder is bitwise invisible ----------------------------

/// One recorded op script (submits, weight updates, partial drains).
#[derive(Clone)]
enum Op {
    Submit(Job),
    Update(Delta),
    Drain,
}

/// Replay one script on a fresh server, concatenating drain outcomes.
fn replay(
    store: EpochStore,
    cfg: StreamConfig,
    ann: Option<Arc<AnnIndex>>,
    ops: &[Op],
) -> Result<(Vec<StreamOutcome>, flip::metrics::StreamStats), String> {
    let mut srv = StreamServer::new(store, cfg);
    if let Some(ix) = ann {
        srv = srv.with_ann(ix);
    }
    let mut out = Vec::new();
    for op in ops {
        match op {
            Op::Submit(job) => {
                srv.submit(*job).map_err(|e| e.to_string())?;
            }
            Op::Update(d) => {
                srv.apply_update(d)?;
            }
            Op::Drain => out.extend(srv.drain_batch()),
        }
    }
    out.extend(srv.drain_all());
    Ok((out, srv.stats().clone()))
}

/// An in-capacity server with `ChaosPlan::none()` and the breaker
/// *enabled* must be bitwise identical — ticket-for-ticket, including
/// epochs, sharing flags and error text — to one with the breaker
/// disabled (the pre-ladder server), across all five job kinds at
/// K = 1 and a sharded K = 2 target. No counter of the ladder may move.
#[test]
fn inert_chaos_and_enabled_breaker_are_bitwise_invisible() {
    drive("inert_chaos_and_enabled_breaker_are_bitwise_invisible", 0x0B5, 2, |x| {
        let g = common::random_graph(&mut |n| x.below(n), 24, 40);
        let n = g.num_vertices() as u64;
        let cfg = ArchConfig::default();
        let cseed = x.next_u64();
        let emb = Embeddings::clustered(g.num_vertices(), 8, 4, x.next_u64());
        let params = AnnParams { beam: 6, k: 3, ..AnnParams::default() };
        let ix = Arc::new(AnnIndex::build(&g, &emb, 1, &cfg, cseed, params));
        for k in [1usize, 2] {
            let mut ops = Vec::new();
            let mut cur = g.clone();
            for _ in 0..24 {
                match x.below(8) {
                    0..=4 => {
                        let kinds = if k == 1 { 5 } else { 3 };
                        let job = match x.below(kinds) {
                            0 => Job::Workload(Workload::Bfs, x.below(n) as u32),
                            1 => Job::Workload(Workload::Sssp, x.below(n) as u32),
                            2 => Job::Workload(Workload::Wcc, x.below(n) as u32),
                            3 => Job::Navigate {
                                source: x.below(n) as u32,
                                target: x.below(n) as u32,
                            },
                            _ => Job::AnnSearch(x.below(n) as u32),
                        };
                        ops.push(Op::Submit(job));
                    }
                    5..=6 => {
                        let d = random_weight_delta(&cur, x);
                        cur.apply_delta(&d)?;
                        ops.push(Op::Update(d));
                    }
                    _ => ops.push(Op::Drain),
                }
            }
            let store = || -> EpochStore {
                if k == 1 {
                    EpochStore::new_single(CompiledPair::build(&g, &cfg, cseed))
                        .with_navigation(4)
                } else {
                    EpochStore::new_sharded(ShardedPair::build(&g, k, &cfg, cseed))
                }
            };
            let base = || StreamConfig { workers: 2, max_batch: 6, ..Default::default() };
            let ladder = base(); // breaker enabled by default, chaos none
            let plain = StreamConfig {
                breaker: BreakerConfig { enabled: false, ..BreakerConfig::default() },
                ..base()
            };
            let ann = if k == 1 { Some(Arc::clone(&ix)) } else { None };
            let (a, sa) = replay(store(), ladder, ann.clone(), &ops)?;
            let (b, sb) = replay(store(), plain, ann, &ops)?;
            if a.len() != b.len() {
                return Err(format!("K={k}: {} vs {} outcomes", a.len(), b.len()));
            }
            for (oa, ob) in a.iter().zip(&b) {
                if !same_outcome(oa, ob) {
                    return Err(format!(
                        "K={k}: ticket {} diverged under the inert ladder",
                        oa.id
                    ));
                }
                if oa.degraded.is_some() {
                    return Err(format!("K={k}: ticket {} degraded at rest", oa.id));
                }
            }
            for (who, st) in [("ladder", &sa), ("plain", &sb)] {
                if st.shed != 0
                    || st.degraded != 0
                    || st.breaker_trips != 0
                    || st.breaker_probes != 0
                    || st.chaos_panics != 0
                    || st.epoch_build_failures != 0
                {
                    return Err(format!("K={k}: {who} server moved a ladder counter at rest"));
                }
            }
        }
        Ok(())
    });
}

// ---- 2. overload sheds only best-effort, and every ticket is counted ----

/// A deterministic overload script derived from measured capacity:
/// deadline budget `cmax + cmin/2` modeled cycles, then a best-effort
/// ticket queued behind an interactive burst. Admission pressure must
/// shed new best-effort/batch tickets with the live backlog in the
/// typed error, the CoDel sweep must evict the overdue queued
/// best-effort ticket as a `Shed` outcome, interactive queries must all
/// complete within budget, and the ledger must conserve — under a
/// seeded wall-clock-only chaos plan (slowdowns + drain stalls), which
/// must not move a single modeled number.
#[test]
fn overload_sheds_only_best_effort_and_conserves_every_ticket() {
    drive("overload_sheds_only_best_effort_and_conserves_every_ticket", 0x0B6, 2, |x| {
        let g = common::random_graph(&mut |n| x.below(n), 20, 32);
        let n = g.num_vertices() as u64;
        let cfg = ArchConfig::default();
        let cseed = x.next_u64();
        let pair = CompiledPair::build(&g, &cfg, cseed);
        let j0 = Job::Workload(Workload::Bfs, x.below(n) as u32);
        let j1 = Job::Workload(Workload::Sssp, x.below(n) as u32);
        let (c0, c1) = (measured_cycles(&pair, j0)?, measured_cycles(&pair, j1)?);
        let (cmin, cmax) = (c0.min(c1), c0.max(c1));
        // every single run fits the budget; a drain's worth of backlog
        // (c0 + c1 = cmax + cmin) strictly exceeds it
        let budget = cmax + cmin / 2;
        let chaos = ChaosPlan::seeded(x.next_u64())
            .with_panic_rate(0.0)
            .with_fatal_rate(0.0)
            .with_build_fail_rate(0.0);
        let mut srv = StreamServer::new(
            EpochStore::new_single(pair),
            StreamConfig {
                workers: 1,
                max_batch: 2,
                queue_depth: 4,
                policy: ServePolicy { deadline: Some(budget), ..Default::default() },
                chaos,
                ..Default::default()
            },
        );
        let sub = |srv: &mut StreamServer, job, pri| -> Result<u64, String> {
            srv.submit_with(job, pri).map_err(|e| e.to_string())
        };
        let mut out = Vec::new();
        // warm-up: populate the latency histograms and the modeled clock
        sub(&mut srv, j0, Priority::BestEffort)?;
        sub(&mut srv, j1, Priority::Interactive)?;
        out.extend(srv.drain_batch());
        // a best-effort ticket queued behind a two-deep interactive burst
        let be_ticket = sub(&mut srv, j0, Priority::BestEffort)?;
        sub(&mut srv, j0, Priority::Interactive)?;
        sub(&mut srv, j1, Priority::Interactive)?;
        // pressure: p99 (= cmax) × 3 pending > budget ⇒ typed shed with
        // the live backlog, best-effort first …
        match srv.submit_with(j1, Priority::BestEffort) {
            Err(AdmissionError::Shed { backlog, budget: b }) => {
                if backlog != 3 * cmax || b != budget {
                    return Err(format!("shed reported backlog {backlog}/{b}"));
                }
            }
            other => return Err(format!("expected pressure shed, got {other:?}")),
        }
        // … then batch traffic once the queue is half full
        if !matches!(srv.submit_with(j0, Priority::Batch), Err(AdmissionError::Shed { .. })) {
            return Err("batch ticket admitted through heavy pressure".into());
        }
        // interactive is never pressure-shed — only hard backpressure
        sub(&mut srv, j0, Priority::Interactive)?;
        match srv.submit_with(j1, Priority::Interactive) {
            Err(AdmissionError::QueueFull { depth: 4 }) => {}
            other => return Err(format!("expected QueueFull {{ depth: 4 }}, got {other:?}")),
        }
        // first drain serves the interactive burst past the waiting
        // best-effort ticket; the second finds it overdue and sheds it
        out.extend(srv.drain_batch());
        out.extend(srv.drain_batch());
        out.extend(srv.drain_all());
        let shed: Vec<&StreamOutcome> = out
            .iter()
            .filter(|o| matches!(&o.result, Err(e) if e.kind == QueryErrorKind::Shed))
            .collect();
        if shed.len() != 1 || shed[0].id != be_ticket {
            return Err(format!("CoDel sweep shed {} tickets, wanted exactly ours", shed.len()));
        }
        if shed[0].priority != Priority::BestEffort {
            return Err("a non-best-effort ticket was queue-shed".into());
        }
        match &shed[0].result {
            Err(e) if e.msg.contains("shed") && e.cycles == 0 => {}
            r => return Err(format!("shed outcome is not a typed zero-cost drop: {r:?}")),
        }
        for o in out.iter().filter(|o| o.priority == Priority::Interactive) {
            match &o.result {
                Ok(q) if q.run.cycles <= budget => {}
                r => return Err(format!("interactive ticket {} missed: {r:?}", o.id)),
            }
        }
        let st = srv.stats();
        if st.submitted != st.served + st.failed + st.shed + st.rejected {
            return Err(format!(
                "ledger leak: {} submitted vs {} served + {} failed + {} shed + {} rejected",
                st.submitted, st.served, st.failed, st.shed, st.rejected
            ));
        }
        if (st.submitted, st.served, st.failed, st.shed, st.rejected) != (9, 5, 0, 3, 1) {
            return Err(format!(
                "counter drift: submitted {} served {} failed {} shed {} rejected {}",
                st.submitted, st.served, st.failed, st.shed, st.rejected
            ));
        }
        if st.chaos_panics != 0 || st.breaker_trips != 0 || st.degraded != 0 {
            return Err("wall-clock-only chaos moved a modeled counter".into());
        }
        Ok(())
    });
}

// ---- 3. breaker: trip, stale reads, probe recovery ----------------------

/// Three consecutive injected fatals trip the (Bfs, single) slot. While
/// it is open the graph moves one epoch forward, and the next arrival
/// degrades to a stale read of the newest healthy epoch — bitwise what
/// a batch engine computes over a stop-the-world recompile of that
/// epoch, staleness reported. With chaos lifted, the scheduled probe
/// half-opens the slot, succeeds at the current epoch, closes it, and
/// exact serving resumes.
#[test]
fn breaker_trips_serves_stale_and_recovers_exact() {
    drive("breaker_trips_serves_stale_and_recovers_exact", 0x0B7, 2, |x| {
        let g0 = common::random_graph(&mut |n| x.below(n), 20, 32);
        let n = g0.num_vertices() as u64;
        let cfg = ArchConfig::default();
        let cseed = x.next_u64();
        let job = Job::Workload(Workload::Bfs, x.below(n) as u32);
        let mut srv = StreamServer::new(
            EpochStore::new_single(CompiledPair::build(&g0, &cfg, cseed)),
            StreamConfig {
                workers: 1,
                max_batch: 1,
                breaker: BreakerConfig { enabled: true, threshold: 3, probe_interval: 2 },
                ..Default::default()
            },
        );
        let sub = |srv: &mut StreamServer| srv.submit(job).map_err(|e| e.to_string());
        // one healthy drain seeds the last-good epoch (version 0); a held
        // pin keeps that snapshot alive the way in-flight queries do
        sub(&mut srv)?;
        let clean = srv.drain_all();
        if clean[0].result.is_err() || clean[0].degraded.is_some() {
            return Err("healthy warm-up drain failed".into());
        }
        let pin0 = srv.store().pin();
        // certain injected fatals: three consecutive trip the slot
        srv.set_chaos(
            ChaosPlan::seeded(x.next_u64())
                .with_fatal_rate(1.0)
                .with_panic_rate(0.0)
                .with_slow_rate(0.0)
                .with_stall_rate(0.0)
                .with_build_fail_rate(0.0),
        );
        for i in 0..3 {
            sub(&mut srv)?;
            let o = srv.drain_all();
            match &o[0].result {
                Err(e) if e.kind == QueryErrorKind::Fatal && e.msg.contains("chaos-injected") => {}
                r => return Err(format!("injected fatal {i} surfaced as {r:?}")),
            }
        }
        if srv.breaker_state(JobClass::Bfs, false) != BreakerState::Open {
            return Err("three consecutive fatals left the slot closed".into());
        }
        if srv.stats().breaker_trips != 1 {
            return Err(format!("{} trips recorded, wanted 1", srv.stats().breaker_trips));
        }
        // the graph moves on while the slot is open
        let d = random_weight_delta(&g0, x);
        let mut g1 = g0.clone();
        g1.apply_delta(&d)?;
        srv.apply_update(&d)?;
        // open slot, arrival 1 of 2: degrade to the last good epoch
        sub(&mut srv)?;
        let deg = srv.drain_all();
        let o = &deg[0];
        if o.degraded != Some(Degraded::Stale { staleness: 1 }) || o.epoch != 0 {
            return Err(format!(
                "open-slot arrival served {:?} at epoch {}, wanted Stale{{1}} at 0",
                o.degraded, o.epoch
            ));
        }
        let oracle0 = CompiledPair::build(&g0, &cfg, cseed);
        let want = Engine::new(&oracle0).with_workers(1).serve(&[job]);
        match (&o.result, &want.results[0]) {
            (Ok(a), Ok(b))
                if a.run.cycles == b.run.cycles
                    && a.run.attrs == b.run.attrs
                    && a.run.sim == b.run.sim => {}
            _ => return Err("stale read != engine over a recompile of epoch 0".into()),
        }
        // health restored: arrival 2 of 2 is the scheduled probe — it
        // runs for real at the current epoch and closes the slot
        srv.set_chaos(ChaosPlan::none());
        sub(&mut srv)?;
        let probed = srv.drain_all();
        let p = &probed[0];
        if p.degraded.is_some() || p.epoch != 1 {
            return Err("the probe did not serve exactly at the live epoch".into());
        }
        let oracle1 = CompiledPair::build(&g1, &cfg, cseed);
        let want = Engine::new(&oracle1).with_workers(1).serve(&[job]);
        match (&p.result, &want.results[0]) {
            (Ok(a), Ok(b))
                if a.run.cycles == b.run.cycles
                    && a.run.attrs == b.run.attrs
                    && a.run.sim == b.run.sim => {}
            _ => return Err("probe answer != engine over a recompile of epoch 1".into()),
        }
        if srv.breaker_state(JobClass::Bfs, false) != BreakerState::Closed {
            return Err("a successful probe must close the slot".into());
        }
        if srv.stats().breaker_probes < 1 {
            return Err("the probe was not counted".into());
        }
        // exact serving has resumed for good
        sub(&mut srv)?;
        let after = srv.drain_all();
        if after[0].result.is_err() || after[0].degraded.is_some() || after[0].epoch != 1 {
            return Err("post-recovery serving is not exact".into());
        }
        let st = srv.stats();
        if st.degraded != 1 || st.staleness.count() != 1 || st.staleness.max() != 1 {
            return Err("exactness-loss accounting drifted".into());
        }
        if st.submitted != st.served + st.failed + st.shed + st.rejected {
            return Err("ledger leak across the breaker episode".into());
        }
        drop(pin0);
        Ok(())
    });
}

// ---- 4. worker panics fail one ticket, not the server -------------------

/// With `p_panic = 1.0` every drained unit's worker panics; each panic
/// must surface as a typed `Fatal` outcome for exactly its own ticket
/// (counted in `chaos_panics`), and once the plan is lifted the same
/// server serves exactly again — a panicking worker never poisons the
/// machines or the queue.
#[test]
fn injected_worker_panics_fail_only_their_ticket() {
    drive("injected_worker_panics_fail_only_their_ticket", 0x0B8, 2, |x| {
        let g = common::random_graph(&mut |n| x.below(n), 20, 32);
        let n = g.num_vertices() as u64;
        let pair = CompiledPair::build(&g, &ArchConfig::default(), x.next_u64());
        let mut srv = StreamServer::new(
            EpochStore::new_single(pair),
            StreamConfig {
                workers: 1,
                max_batch: 4,
                breaker: BreakerConfig { enabled: false, ..BreakerConfig::default() },
                ..Default::default()
            },
        );
        srv.set_chaos(
            ChaosPlan::seeded(x.next_u64())
                .with_panic_rate(1.0)
                .with_fatal_rate(0.0)
                .with_slow_rate(0.0)
                .with_stall_rate(0.0)
                .with_build_fail_rate(0.0),
        );
        let j0 = Job::Workload(Workload::Bfs, x.below(n) as u32);
        let j1 = Job::Workload(Workload::Sssp, x.below(n) as u32);
        srv.submit(j0).map_err(|e| e.to_string())?;
        srv.submit(j1).map_err(|e| e.to_string())?;
        let out = srv.drain_all();
        if out.len() != 2 {
            return Err(format!("{} outcomes for 2 panicking tickets", out.len()));
        }
        for o in &out {
            match &o.result {
                Err(e) if e.kind == QueryErrorKind::Fatal && e.msg.contains("worker panicked") => {}
                r => return Err(format!("ticket {} panic surfaced as {r:?}", o.id)),
            }
        }
        if srv.stats().chaos_panics != 2 {
            return Err(format!("{} panics counted, wanted 2", srv.stats().chaos_panics));
        }
        // the same server, plan lifted: exact serving resumes
        srv.set_chaos(ChaosPlan::none());
        srv.submit(j0).map_err(|e| e.to_string())?;
        let after = srv.drain_all();
        if after[0].result.is_err() || after[0].degraded.is_some() {
            return Err("server did not survive its own workers".into());
        }
        let st = srv.stats();
        if st.submitted != st.served + st.failed + st.shed + st.rejected {
            return Err("ledger leak across the panic episode".into());
        }
        Ok(())
    });
}

// ---- 5. backpressure telemetry is truthful ------------------------------

/// `QueueFull` must carry the *live* pending depth (and render it), not
/// a stale configured constant — and clear after a drain.
#[test]
fn queue_full_reports_the_live_depth() {
    let mut x = XorShift::new(0x0F11);
    let g = common::random_graph(&mut |n| x.below(n), 16, 24);
    let pair = CompiledPair::build(&g, &ArchConfig::default(), 7);
    let mut srv = StreamServer::new(
        EpochStore::new_single(pair),
        StreamConfig { workers: 1, max_batch: 4, queue_depth: 2, ..Default::default() },
    );
    let job = Job::Workload(Workload::Bfs, 0);
    srv.submit(job).unwrap();
    srv.submit(job).unwrap();
    let err = srv.submit(job).unwrap_err();
    assert_eq!(err, AdmissionError::QueueFull { depth: 2 });
    assert!(err.to_string().contains("2 pending"), "Display must name the live depth: {err}");
    srv.drain_all();
    assert!(srv.submit(job).is_ok(), "backpressure clears after a drain");
    assert_eq!(srv.stats().rejected, 1);
}

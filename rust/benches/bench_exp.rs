//! Bench: regenerate EVERY table and figure of the paper (quick scale) —
//! the single entry point that reproduces the evaluation section.
//! `cargo bench --bench bench_exp` prints the paper-shaped rows.

mod common;

use flip::experiments::{registry, ExpEnv};

fn main() {
    let mut env = ExpEnv::quick();
    env.graphs_per_group = 3;
    env.sources_per_graph = 2;
    // keep Ext. LRN light under the bench harness
    let heavy = ["scalability"];
    for (id, desc, driver) in registry() {
        common::section(&format!("{id} — {desc}"));
        let mut e = env.clone();
        if heavy.contains(&id) {
            e.graphs_per_group = 1;
        }
        let t0 = std::time::Instant::now();
        match driver(&e) {
            Ok(text) => {
                println!("{text}");
                println!("[{id} regenerated in {:.1}s]", t0.elapsed().as_secs_f64());
            }
            Err(err) => println!("[{id} FAILED: {err}]"),
        }
    }
}

//! Bench: operation-centric baseline — modulo scheduling (the classic
//! CGRA compile path, Fig 13a) and execution-model throughput.

mod common;

use flip::config::ArchConfig;
use flip::graph::datasets::{self, Group};
use flip::sim::{modulo, opcentric};
use flip::workloads::{dfgs, Workload};

fn main() {
    let cfg = ArchConfig::default();
    common::section("Modulo scheduling + SA placement (per kernel)");
    for (name, d) in [
        ("BFS u1", dfgs::bfs_dfg()),
        ("BFS u3", dfgs::bfs_dfg().unrolled(3)),
        ("WCC u1", dfgs::wcc_dfg()),
        ("SSSP search", dfgs::sssp_search_dfg()),
        ("SSSP update", dfgs::sssp_update_dfg()),
    ] {
        let mut out = None;
        common::bench(&format!("map {name} ({} ops)", d.num_ops()), 1, 5, || {
            out = modulo::map(&d, cfg.array_w, cfg.array_h, 1, 64);
        });
        let s = out.unwrap();
        println!("    -> II={} length={} routing={}", s.ii, s.length, s.routing_cost);
    }

    common::section("Op-centric execution model");
    let g = datasets::generate_one(Group::Lrn, 0, 42);
    for w in Workload::ALL {
        let k = opcentric::compile_kernel(w, &cfg, 1, 1).unwrap();
        let mut cycles = 0;
        common::bench(&format!("{} on LRN", w.name()), 2, 10, || {
            cycles = opcentric::run(&k, &g, 0).cycles;
        });
        println!("    -> {cycles} modeled cycles");
    }
}

//! Bench: cycle-accurate FLIP simulator throughput — the L3 hot path.
//! Reports wall time per run and simulated PE-cycles/second (the §Perf
//! target in DESIGN.md is ≥10M PE-cycles/s for the event-driven core),
//! compares against the retained naive reference stepper so the
//! scheduler speedup is part of the recorded trajectory, and tracks the
//! serve path: engine `queries_per_s` over all workers and the
//! `reset_reuse_speedup` of a reused SimInstance vs per-query cold
//! starts (DESIGN.md §6; expected ≥ 1.0×). The dispatch-and-layout
//! section records `dyn_vs_mono_speedup` (monomorphized event core vs
//! its own dyn-shim instantiation, incl. the 16k Ext. LRN graph) and
//! `table_scan_ns_per_delivery` (host ns per delivered packet — the CSR
//! slab walk cost). The fault-model section records `fault_overhead_pct`
//! (host cost of the quiet active plan's seq+checksum handshake on the
//! 16k Ext. LRN sharded run; expected ≈ 0) and, for a seeded lossy-link
//! serving run, `retry_success_rate` / `deadline_abort_pct` from the
//! engine's batch report (DESIGN.md §8). The batching section
//! (DESIGN.md §Perf.2) records `batch_speedup` (one fused 8-lane
//! `BatchInstance` pass vs 8 sequential reused-`SimInstance` runs on the
//! 16k Ext. LRN graph), `delivery_ns_per_entry` (host ns per intra-table
//! entry walked on the fused pass), and `superstep_parallel_speedup`
//! (pooled vs serial lockstep supersteps on a 4-shard fabric, with a
//! bitwise-equality gate on the pooled merge).
//!
//! Writes `BENCH_flip_sim.json` (override with `--json <path>`).

mod common;

use flip::compiler::{compile, CompileOpts};
use flip::config::ArchConfig;
use flip::experiments::harness::CompiledPair;
use flip::graph::datasets::{self, Group};
use flip::service::{Engine, Job, ServePolicy};
use flip::sim::batch::BatchInstance;
use flip::sim::flip::{run, run_program, SimInstance, SimOptions};
use flip::sim::FaultPlan;
use flip::sim::naive;
use flip::util::WorkerPool;
use flip::workloads::program::VertexProgram;
use flip::workloads::{with_builtin, Workload};

/// One dispatch-and-layout datapoint: time the monomorphized
/// (`with_builtin`) run path against its dyn-shim instantiation on one
/// (compiled graph, workload) config and record `dyn_vs_mono_speedup`,
/// `table_scan_ns_per_delivery` — host wall-ns per *delivered packet* on
/// the mono core, an end-to-end per-delivery figure whose dominant
/// per-packet table cost is the CSR bucket walk (it also includes ALU,
/// scatter and scheduler time) — and `pe_cycles_per_s`. One derivation,
/// so the Lrn and 16k Ext. LRN JSON entries cannot drift apart.
fn bench_dispatch_layout(
    suite: &mut common::Suite,
    cfg: &ArchConfig,
    c: &flip::compiler::CompiledGraph,
    w: Workload,
    opts: &SimOptions,
    mono_label: &str,
    reps: (u32, u32),
) {
    let (warmup, iters) = reps;
    let mut delivered = 0u64;
    let mut cycles = 0u64;
    let mono = common::bench(mono_label, warmup, iters, || {
        let r = with_builtin(w, |p| run_program(c, p, 0, opts)).unwrap();
        delivered = r.sim.packets_delivered;
        cycles = r.cycles;
    });
    let vp: Box<dyn VertexProgram> = w.builtin_program();
    // unique JSON entry name per config: the sink is diffed PR-over-PR
    let shim_label = format!("{mono_label}, dyn-shim");
    let shim = common::bench(&shim_label, warmup, iters, || {
        run_program(c, vp.as_ref(), 0, opts).unwrap();
    });
    let dyn_vs_mono = shim.mean_ms / mono.mean_ms;
    let scan_ns = mono.mean_ms * 1e6 / delivered.max(1) as f64;
    let pe_cycles_per_s = cycles as f64 * cfg.num_pes() as f64 / (mono.mean_ms / 1e3);
    println!(
        "    -> dyn/mono {dyn_vs_mono:.2}x, {scan_ns:.0} ns per delivered packet \
         ({delivered} deliveries), {:.1}M simulated PE-cycles/s",
        pe_cycles_per_s / 1e6
    );
    suite
        .add(mono)
        .metric("dyn_vs_mono_speedup", dyn_vs_mono)
        .metric("table_scan_ns_per_delivery", scan_ns)
        .metric("pe_cycles_per_s", pe_cycles_per_s);
    suite.add(shim);
}

fn main() {
    let cfg = ArchConfig::default();
    let mut suite = common::Suite::new("flip_sim");
    common::section("FLIP cycle-accurate simulator (event-driven core)");
    for (group, w) in [
        (Group::Lrn, Workload::Bfs),
        (Group::Lrn, Workload::Sssp),
        (Group::Lrn, Workload::Wcc),
        (Group::Syn, Workload::Wcc),
    ] {
        let g = datasets::generate_one(group, 0, 42);
        let view = flip::workloads::view_for(w, &g);
        let c = compile(&view, &cfg, &CompileOpts::default());
        let mut cycles = 0u64;
        let r = common::bench(
            &format!(
                "{} on {} (|V|={} |E|={})",
                w.name(),
                group.name(),
                g.num_vertices(),
                g.num_edges()
            ),
            2,
            10,
            || {
                let r = run(&c, w, 0, &SimOptions::default()).unwrap();
                cycles = r.cycles;
            },
        );
        let pe_cycles_per_s = cycles as f64 * cfg.num_pes() as f64 / (r.mean_ms / 1e3);
        println!(
            "    -> {} sim cycles/run, {:.1}M simulated PE-cycles/s",
            cycles,
            pe_cycles_per_s / 1e6
        );
        suite.add(r).metric("sim_cycles", cycles as f64).metric(
            "pe_cycles_per_s",
            pe_cycles_per_s,
        );
    }

    common::section("event-driven core vs naive reference stepper (Lrn BFS)");
    let g = datasets::generate_one(Group::Lrn, 0, 42);
    let c = compile(&g, &cfg, &CompileOpts::default());
    let fast =
        common::bench("event-driven core", 1, 5, || {
            run(&c, Workload::Bfs, 0, &SimOptions::default()).unwrap();
        });
    let slow = common::bench("naive reference stepper", 1, 5, || {
        naive::run(&c, Workload::Bfs, 0, &SimOptions::default()).unwrap();
    });
    let speedup = slow.mean_ms / fast.mean_ms;
    println!("    -> scheduler speedup {speedup:.2}x over naive");
    suite.add(fast).metric("speedup_vs_naive", speedup);
    suite.add(slow);

    common::section("FLIP simulator with data swapping (2 copies)");
    let g = flip::graph::generate::road_network(384, 880, 1100, 9);
    let c = compile(&g, &cfg, &CompileOpts::default());
    let opts = SimOptions { max_cycles: 1_000_000_000, watchdog: 5_000_000, ..Default::default() };
    let fast = common::bench("BFS with slice swapping (|V|=384)", 1, 5, || {
        run(&c, Workload::Bfs, 0, &opts).unwrap();
    });
    let slow = common::bench("  same, naive stepper", 1, 3, || {
        naive::run(&c, Workload::Bfs, 0, &opts).unwrap();
    });
    let speedup = slow.mean_ms / fast.mean_ms;
    println!("    -> fast-forward speedup {speedup:.2}x over naive on the swapping path");
    suite.add(fast).metric("speedup_vs_naive", speedup);
    suite.add(slow);

    common::section("dispatch & layout: monomorphized core vs dyn shim (Lrn BFS)");
    let g = datasets::generate_one(Group::Lrn, 0, 42);
    let c = compile(&g, &cfg, &CompileOpts::default());
    bench_dispatch_layout(
        &mut suite,
        &cfg,
        &c,
        Workload::Bfs,
        &SimOptions::default(),
        "monomorphized run path (with_builtin)",
        (2, 8),
    );

    common::section("dispatch & layout at scale: 16k Ext. LRN (SSSP, swapping)");
    let g16 = datasets::generate_one(Group::ExtLrn, 0, 42);
    let c16 = compile(&g16, &cfg, &CompileOpts::default());
    let opts16 =
        SimOptions { max_cycles: 2_000_000_000, watchdog: 5_000_000, ..Default::default() };
    let label16 =
        format!("monomorphized (|V|={}, {} copies)", g16.num_vertices(), c16.placement.num_copies);
    bench_dispatch_layout(&mut suite, &cfg, &c16, Workload::Sssp, &opts16, &label16, (0, 2));

    common::section("query-serving engine (compile once, serve many)");
    let g = datasets::generate_one(Group::Lrn, 0, 42);
    let pair = CompiledPair::build(&g, &cfg, 42);
    let n = g.num_vertices() as u32;
    let batch = 64usize;
    let jobs: Vec<Job> = (0..batch)
        .map(|i| {
            Job::Workload([Workload::Bfs, Workload::Sssp][i % 2], (i as u32 * 13) % n)
        })
        .collect();
    let mut engine = Engine::new(&pair);
    let workers = engine.workers();
    let mut batch_cycles = 0u64;
    let r = common::bench(
        &format!("engine: {batch} bfs/sssp queries ({workers} workers)"),
        1,
        5,
        || {
            let rep = engine.serve(&jobs);
            assert!(rep.first_error().is_none(), "engine batch failed");
            batch_cycles = rep.sim_cycles;
        },
    );
    let queries_per_s = batch as f64 / (r.mean_ms / 1e3);
    let engine_pe_cycles_per_s =
        batch_cycles as f64 * cfg.num_pes() as f64 / (r.mean_ms / 1e3);
    println!(
        "    -> {queries_per_s:.0} queries/s, {:.1}M simulated PE-cycles/s across workers",
        engine_pe_cycles_per_s / 1e6
    );
    suite
        .add(r)
        .metric("queries_per_s", queries_per_s)
        .metric("engine_pe_cycles_per_s", engine_pe_cycles_per_s);

    // same Lrn graph the engine section bound above
    common::section("multi-chip sharded fabric (Lrn BFS, lockstep supersteps)");
    for k in [2usize, 4] {
        let m = flip::sim::multichip::ShardedMachine::build(&g, k, &cfg, 42);
        let mut insts = m.new_instances();
        let vp = Workload::Bfs.builtin_program();
        let mut sharded_cycles = 0u64;
        let mut chip_pkts = 0u64;
        let mut traffic_pct = 0.0f64;
        let mut sharded_mteps = 0.0f64;
        let r = common::bench(&format!("BFS on {k} shards (|V|={})", g.num_vertices()), 1, 5, || {
            let r = flip::sim::multichip::run_program(
                &m,
                &mut insts,
                vp.as_ref(),
                0,
                &SimOptions::default(),
            )
            .unwrap();
            sharded_cycles = r.result.cycles;
            chip_pkts = r.result.sim.chip_packets;
            traffic_pct = r.result.sim.chip_packets as f64
                / r.result.sim.packets_delivered.max(1) as f64
                * 100.0;
            sharded_mteps = r.result.mteps(cfg.freq_mhz);
        });
        println!(
            "    -> {sharded_cycles} lockstep cycles, {chip_pkts} inter-chip packets \
             ({traffic_pct:.1}% of deliveries), {sharded_mteps:.2} MTEPS"
        );
        suite
            .add(r)
            .metric("shards", k as f64)
            .metric("sharded_cycles", sharded_cycles as f64)
            .metric("sharded_mteps", sharded_mteps)
            .metric("chip_packets", chip_pkts as f64)
            .metric("cut_traffic_pct", traffic_pct);
    }

    common::section("SimInstance reuse vs per-query cold start (Lrn SSSP x16)");
    let sources: Vec<u32> = (0..16u32).map(|i| (i * 17) % n).collect();
    let c = &pair.directed;
    let mut inst = SimInstance::new(c);
    let reuse = common::bench("reused SimInstance (reset per query)", 1, 5, || {
        for &s in &sources {
            inst.run(c, Workload::Sssp, s, &SimOptions::default()).unwrap();
        }
    });
    let cold = common::bench("fresh machine per query (cold start)", 1, 5, || {
        for &s in &sources {
            run(c, Workload::Sssp, s, &SimOptions::default()).unwrap();
        }
    });
    let reset_reuse_speedup = cold.mean_ms / reuse.mean_ms;
    println!("    -> reset-reuse speedup {reset_reuse_speedup:.2}x over per-query cold start");
    suite.add(reuse).metric("reset_reuse_speedup", reset_reuse_speedup);
    suite.add(cold);

    common::section("fault machinery overhead: quiet active plan (16k Ext. LRN, 2 shards)");
    let m16 = flip::sim::multichip::ShardedMachine::build(&g16, 2, &cfg, 42);
    let vp16 = Workload::Sssp.builtin_program();
    let mut insts16 = m16.new_instances();
    let plain = common::bench("sharded 16k SSSP, no fault plan", 0, 2, || {
        flip::sim::multichip::run_program(&m16, &mut insts16, vp16.as_ref(), 0, &opts16).unwrap();
    });
    // rates 0.0: the seq/checksum handshake and recovery bookkeeping run
    // on every cut packet, but nothing fires — overhead should be noise
    let quiet16 = SimOptions {
        faults: FaultPlan::seeded(42).with_link_rate(0.0).with_stall_rate(0.0),
        ..opts16.clone()
    };
    let quiet = common::bench("  same, quiet active plan (seq+checksum handshake)", 0, 2, || {
        flip::sim::multichip::run_program(&m16, &mut insts16, vp16.as_ref(), 0, &quiet16).unwrap();
    });
    let fault_overhead_pct = (quiet.mean_ms / plain.mean_ms - 1.0) * 100.0;
    println!("    -> fault handshake overhead {fault_overhead_pct:+.1}% host time");
    suite.add(plain).metric("fault_overhead_pct", fault_overhead_pct);
    suite.add(quiet);

    common::section("deadline-budgeted serving on a lossy fabric (Lrn, 2 shards)");
    let spair = flip::experiments::harness::ShardedPair::build(&g, 2, &cfg, 42);
    // budget each query at 4x a clean SSSP, so most retries fit but an
    // unlucky streak aborts on its deadline instead of hanging
    let probe = flip::sim::multichip::run(&spair.directed, Workload::Sssp, 0, &SimOptions::default())
        .unwrap()
        .result
        .cycles;
    let lossy = FaultPlan::seeded(0xFA17).with_link_rate(0.35).with_max_retransmits(1);
    let mut engine = Engine::new_sharded(&spair)
        .with_opts(SimOptions { faults: lossy, ..Default::default() })
        .with_policy(ServePolicy { deadline: Some(4 * probe), max_retries: 3 });
    let jobs: Vec<Job> = (0..32usize)
        .map(|i| Job::Workload([Workload::Bfs, Workload::Sssp][i % 2], (i as u32 * 29) % n))
        .collect();
    let mut served_ok = 0usize;
    let mut aborts = 0u64;
    let mut batch_retries = 0u64;
    let r = common::bench("engine: 32 queries, lossy links, 3 retries", 1, 3, || {
        let rep = engine.serve(&jobs);
        served_ok = rep.results.iter().filter(|r| r.is_ok()).count();
        aborts = rep.deadline_aborts;
        batch_retries = rep.retries;
    });
    let retry_success_rate = served_ok as f64 / jobs.len() as f64;
    let deadline_abort_pct = aborts as f64 / jobs.len() as f64 * 100.0;
    println!(
        "    -> {served_ok}/{} answered ({batch_retries} retries), \
         {deadline_abort_pct:.1}% deadline aborts",
        jobs.len()
    );
    suite
        .add(r)
        .metric("retry_success_rate", retry_success_rate)
        .metric("deadline_abort_pct", deadline_abort_pct)
        .metric("retries", batch_retries as f64);

    common::section("streaming server: admission, epochs, frontier sharing (Lrn)");
    use flip::graph::Delta;
    use flip::service::stream::{EpochStore, StreamConfig, StreamServer};
    // 96 queries in runs of 4 identical (epoch, job) pairs — the sharing
    // fan-out the admission queue is built for — with an epoch published
    // (and a batch drained) every 24 submits so updates race queries
    let stream_n = 96usize;
    let sjobs: Vec<Job> = (0..stream_n)
        .map(|i| Job::Workload([Workload::Bfs, Workload::Sssp][(i / 4) % 2], ((i as u32 / 4) * 13) % n))
        .collect();
    let mut stream_qps = 0.0f64;
    let mut p99_cycles = 0u64;
    let mut apply_overhead_pct = 0.0f64;
    let mut shared_hits = 0u64;
    let mut sim_runs = 0u64;
    let r = common::bench("stream: 96 queries, 4 epochs, sharing on", 1, 3, || {
        let mut srv =
            StreamServer::new(EpochStore::new_single(pair.clone()), StreamConfig::default());
        let t0 = std::time::Instant::now();
        for (i, &job) in sjobs.iter().enumerate() {
            srv.submit(job).unwrap();
            if i % 24 == 23 {
                let d = {
                    let pin = srv.store().pin();
                    let (u, v, _) = pin.graph().arcs().next().unwrap();
                    Delta::from_edges(pin.graph(), &[(u, v, (i as u32 % 90) + 1)])
                };
                srv.apply_update(&d).unwrap();
                srv.drain_batch();
            }
        }
        srv.drain_all();
        let wall = t0.elapsed().as_secs_f64();
        let st = srv.stats();
        assert_eq!(st.failed, 0, "streaming bench queries must all answer");
        stream_qps = st.completed() as f64 / wall;
        p99_cycles = st.cycles.p99();
        apply_overhead_pct = st.epoch_apply_us as f64 / (wall * 1e6) * 100.0;
        shared_hits = st.shared_hits;
        sim_runs = st.sim_runs;
    });
    println!(
        "    -> {stream_qps:.0} completed queries/s, p99 {p99_cycles} modeled cycles, \
         {shared_hits} of {stream_n} answers fanned out of {sim_runs} runs, \
         epoch apply {apply_overhead_pct:.2}% of wall"
    );
    suite
        .add(r)
        .metric("stream_qps", stream_qps)
        .metric("p99_cycles", p99_cycles as f64)
        .metric("epoch_apply_overhead_pct", apply_overhead_pct)
        .metric("shared_hits", shared_hits as f64)
        .metric("sim_runs", sim_runs as f64);

    common::section("fused batch lanes vs sequential reuse (16k Ext. LRN SSSP x8)");
    let n16 = g16.num_vertices() as u32;
    let bsources: Vec<u32> = (0..8u32).map(|i| (i * 1021) % n16).collect();
    let mut seq_inst = SimInstance::new(&c16);
    let seq = common::bench("sequential: reused SimInstance, 8 queries", 0, 2, || {
        for &s in &bsources {
            seq_inst.run(&c16, Workload::Sssp, s, &opts16).unwrap();
        }
    });
    let mut batch16 = BatchInstance::new(&c16, bsources.len());
    let mut fused_walked = 0u64;
    let fused = common::bench("fused: one 8-lane BatchInstance pass", 0, 2, || {
        let out = batch16.run_workload_batch(&c16, Workload::Sssp, &bsources, &opts16);
        fused_walked =
            out.iter().map(|r| r.as_ref().unwrap().sim.activity.intra_walked).sum();
    });
    let batch_speedup = seq.mean_ms / fused.mean_ms;
    // host ns per delivered intra-table entry across the whole fused
    // sweep — the branchless fixed-stride delivery loop's unit cost
    let delivery_ns_per_entry = fused.mean_ms * 1e6 / fused_walked.max(1) as f64;
    println!(
        "    -> fused 8-lane pass {batch_speedup:.2}x vs sequential reuse, \
         {delivery_ns_per_entry:.1} ns per intra-table entry walked"
    );
    suite
        .add(fused)
        .metric("batch_speedup", batch_speedup)
        .metric("delivery_ns_per_entry", delivery_ns_per_entry);
    suite.add(seq);

    common::section("pooled supersteps vs serial lockstep (Lrn BFS, 4 shards)");
    let m4 = flip::sim::multichip::ShardedMachine::build(&g, 4, &cfg, 42);
    let serial = common::bench("serial supersteps (4 shards)", 1, 5, || {
        flip::sim::multichip::run(&m4, Workload::Bfs, 0, &SimOptions::default()).unwrap();
    });
    let wpool = WorkerPool::new(4);
    let pooled = common::bench("  same, pooled supersteps (4 workers)", 1, 5, || {
        flip::sim::multichip::run_on(&m4, Workload::Bfs, 0, &SimOptions::default(), Some(&wpool))
            .unwrap();
    });
    // determinism gate: the pooled barrier merge must be bitwise
    // identical to the serial shard loop, not just statistically close
    let ser = flip::sim::multichip::run(&m4, Workload::Bfs, 0, &SimOptions::default()).unwrap();
    let par =
        flip::sim::multichip::run_on(&m4, Workload::Bfs, 0, &SimOptions::default(), Some(&wpool))
            .unwrap();
    assert_eq!(ser.result.cycles, par.result.cycles, "pooled supersteps must be deterministic");
    assert_eq!(ser.result.attrs, par.result.attrs, "pooled supersteps must be deterministic");
    let superstep_parallel_speedup = serial.mean_ms / pooled.mean_ms;
    println!("    -> pooled supersteps {superstep_parallel_speedup:.2}x vs serial lockstep");
    suite.add(pooled).metric("superstep_parallel_speedup", superstep_parallel_speedup);
    suite.add(serial);

    common::section("beam-search ANN: query throughput and recall@10 (clustered, |V|=256)");
    use flip::graph::{generate, reference};
    use flip::workloads::ann::{self, AnnIndex, AnnParams, AnnSearcher};
    let (ag, emb) = generate::ann_graph(256, 8, 6, 42);
    let aparams = AnnParams { k: 10, beam: 48, deg: 6, ..AnnParams::default() };
    let ix = AnnIndex::build(&ag, &emb, 1, &cfg, 42, aparams);
    let aopts = SimOptions { max_cycles: 2_000_000_000, watchdog: 5_000_000, ..Default::default() };
    let aqueries: Vec<Vec<u8>> =
        (0..16u32).map(|i| emb.vector((i * 37) % 256).to_vec()).collect();
    let mut searcher = AnnSearcher::new(&ix);
    let mut recall_sum = 0.0f64;
    let mut ann_cycles = 0u64;
    let r = common::bench("ANN: 16 queries, beam 48, reused searcher", 1, 5, || {
        recall_sum = 0.0;
        ann_cycles = 0;
        for qv in &aqueries {
            let r = searcher.search(&ix, qv, &aopts).unwrap();
            recall_sum +=
                reference::recall(&r.neighbors, &reference::knn_exact(&emb, qv, aparams.k));
            ann_cycles += r.cycles;
        }
    });
    let ann_qps = aqueries.len() as f64 / (r.mean_ms / 1e3);
    let ann_recall_at_10 = recall_sum / aqueries.len() as f64;
    // the fabric is bitwise the CPU oracle, so recall is a pure property
    // of (embeddings, graph, beam) — recorded to catch index regressions
    println!(
        "    -> {ann_qps:.0} queries/s, recall@10 {ann_recall_at_10:.3}, \
         {ann_cycles} sim cycles over the batch"
    );
    suite
        .add(r)
        .metric("ann_qps", ann_qps)
        .metric("ann_recall_at_10", ann_recall_at_10)
        .metric("ann_sim_cycles", ann_cycles as f64);
    {
        // fused lanes: same 16 queries through one 8-lane BatchInstance
        let fq: Vec<ann::AnnQuery> =
            aqueries.iter().map(|qv| (qv.clone(), ix.probe(qv))).collect();
        let mut ab = BatchInstance::new(&ix.base().compiled, 8);
        let fused = common::bench("  same, fused 8-lane batch passes", 1, 5, || {
            for chunk in fq.chunks(8) {
                for r in
                    ann::search_batch(&mut ab, &ix.base().compiled, &ag, &emb, chunk, &aparams, &aopts)
                {
                    r.unwrap();
                }
            }
        });
        let ann_batch_speedup = r.mean_ms / fused.mean_ms;
        println!("    -> fused lanes {ann_batch_speedup:.2}x vs reused per-query searcher");
        suite.add(fused).metric("ann_batch_speedup", ann_batch_speedup);
    }

    suite.write().expect("write bench json");
}

//! Bench: cycle-accurate FLIP simulator throughput — the L3 hot path.
//! Reports wall time per run and simulated PE-cycles/second (the §Perf
//! target in DESIGN.md is ≥10M PE-cycles/s for the event-driven core),
//! and compares against the retained naive reference stepper so the
//! scheduler speedup is part of the recorded trajectory.
//!
//! Writes `BENCH_flip_sim.json` (override with `--json <path>`).

mod common;

use flip::compiler::{compile, CompileOpts};
use flip::config::ArchConfig;
use flip::graph::datasets::{self, Group};
use flip::sim::flip::{run, SimOptions};
use flip::sim::naive;
use flip::workloads::Workload;

fn main() {
    let cfg = ArchConfig::default();
    let mut suite = common::Suite::new("flip_sim");
    common::section("FLIP cycle-accurate simulator (event-driven core)");
    for (group, w) in [
        (Group::Lrn, Workload::Bfs),
        (Group::Lrn, Workload::Sssp),
        (Group::Lrn, Workload::Wcc),
        (Group::Syn, Workload::Wcc),
    ] {
        let g = datasets::generate_one(group, 0, 42);
        let view = flip::workloads::view_for(w, &g);
        let c = compile(&view, &cfg, &CompileOpts::default());
        let mut cycles = 0u64;
        let r = common::bench(
            &format!(
                "{} on {} (|V|={} |E|={})",
                w.name(),
                group.name(),
                g.num_vertices(),
                g.num_edges()
            ),
            2,
            10,
            || {
                let r = run(&c, w, 0, &SimOptions::default()).unwrap();
                cycles = r.cycles;
            },
        );
        let pe_cycles_per_s = cycles as f64 * cfg.num_pes() as f64 / (r.mean_ms / 1e3);
        println!(
            "    -> {} sim cycles/run, {:.1}M simulated PE-cycles/s",
            cycles,
            pe_cycles_per_s / 1e6
        );
        suite.add(r).metric("sim_cycles", cycles as f64).metric(
            "pe_cycles_per_s",
            pe_cycles_per_s,
        );
    }

    common::section("event-driven core vs naive reference stepper (Lrn BFS)");
    let g = datasets::generate_one(Group::Lrn, 0, 42);
    let c = compile(&g, &cfg, &CompileOpts::default());
    let fast =
        common::bench("event-driven core", 1, 5, || {
            run(&c, Workload::Bfs, 0, &SimOptions::default()).unwrap();
        });
    let slow = common::bench("naive reference stepper", 1, 5, || {
        naive::run(&c, Workload::Bfs, 0, &SimOptions::default()).unwrap();
    });
    let speedup = slow.mean_ms / fast.mean_ms;
    println!("    -> scheduler speedup {speedup:.2}x over naive");
    suite.add(fast).metric("speedup_vs_naive", speedup);
    suite.add(slow);

    common::section("FLIP simulator with data swapping (2 copies)");
    let g = flip::graph::generate::road_network(384, 880, 1100, 9);
    let c = compile(&g, &cfg, &CompileOpts::default());
    let opts = SimOptions { max_cycles: 1_000_000_000, watchdog: 5_000_000, ..Default::default() };
    let fast = common::bench("BFS with slice swapping (|V|=384)", 1, 5, || {
        run(&c, Workload::Bfs, 0, &opts).unwrap();
    });
    let slow = common::bench("  same, naive stepper", 1, 3, || {
        naive::run(&c, Workload::Bfs, 0, &opts).unwrap();
    });
    let speedup = slow.mean_ms / fast.mean_ms;
    println!("    -> fast-forward speedup {speedup:.2}x over naive on the swapping path");
    suite.add(fast).metric("speedup_vs_naive", speedup);
    suite.add(slow);

    suite.write().expect("write bench json");
}

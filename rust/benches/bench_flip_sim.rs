//! Bench: cycle-accurate FLIP simulator throughput — the L3 hot path.
//! Reports wall time per run and simulated PE-cycles/second (the §Perf
//! target in DESIGN.md is ≥10M PE-cycles/s).

mod common;

use flip::compiler::{compile, CompileOpts};
use flip::config::ArchConfig;
use flip::graph::datasets::{self, Group};
use flip::sim::flip::{run, SimOptions};
use flip::workloads::Workload;

fn main() {
    let cfg = ArchConfig::default();
    common::section("FLIP cycle-accurate simulator");
    for (group, w) in [
        (Group::Lrn, Workload::Bfs),
        (Group::Lrn, Workload::Sssp),
        (Group::Lrn, Workload::Wcc),
        (Group::Syn, Workload::Wcc),
    ] {
        let g = datasets::generate_one(group, 0, 42);
        let view = flip::workloads::view_for(w, &g);
        let c = compile(&view, &cfg, &CompileOpts::default());
        let mut cycles = 0u64;
        let r = common::bench(
            &format!("{} on {} (|V|={} |E|={})", w.name(), group.name(), g.num_vertices(), g.num_edges()),
            2,
            10,
            || {
                let r = run(&c, w, 0, &SimOptions::default()).unwrap();
                cycles = r.cycles;
            },
        );
        let pe_cycles_per_s = cycles as f64 * cfg.num_pes() as f64 / (r.mean_ms / 1e3);
        println!(
            "    -> {} sim cycles/run, {:.1}M simulated PE-cycles/s",
            cycles,
            pe_cycles_per_s / 1e6
        );
    }

    common::section("FLIP simulator with data swapping (2 copies)");
    let g = flip::graph::generate::road_network(384, 880, 1100, 9);
    let c = compile(&g, &cfg, &CompileOpts::default());
    let opts = SimOptions { max_cycles: 1_000_000_000, watchdog: 5_000_000, ..Default::default() };
    common::bench("BFS with slice swapping (|V|=384)", 1, 5, || {
        run(&c, Workload::Bfs, 0, &opts).unwrap();
    });
}

//! Bench: PJRT runtime — AOT artifact dispatch latency and golden-model
//! fixpoint time (§Perf target: 256-vertex fixpoint < 50 ms).

mod common;

use flip::graph::generate;
use flip::runtime::{default_artifact_dir, GoldenEngine};
use flip::workloads::Workload;

fn main() {
    let engine = match GoldenEngine::load(&default_artifact_dir()) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("artifacts not built ({e}); run `make artifacts` first");
            return;
        }
    };
    common::section("PJRT dispatch latency (dense relax)");
    for &n in &[16usize, 64, 256] {
        let d = vec![f32::INFINITY; n];
        let w = vec![f32::INFINITY; n * n];
        common::bench(&format!("relax_step n={n}"), 3, 20, || {
            engine.relax_step(&d, &w, n).unwrap();
        });
        common::bench(&format!("relax_k8  n={n} (scan amortized)"), 3, 20, || {
            engine.relax_k8(&d, &w, n).unwrap();
        });
    }

    common::section("Golden-model fixpoint (graph -> dense -> converged)");
    let g = generate::road_network(256, 584, 650, 3);
    common::bench("BFS golden, |V|=256 (pad 256)", 1, 5, || {
        engine.golden_attrs(&g, Workload::Bfs, 0).unwrap().unwrap();
    });
    let small = generate::road_network(64, 146, 166, 3);
    common::bench("SSSP golden, |V|=64 (pad 64)", 1, 5, || {
        engine.golden_attrs(&small, Workload::Sssp, 0).unwrap().unwrap();
    });
}

//! Minimal bench harness (criterion is not vendored offline): warmup +
//! timed iterations with mean/min/max reporting.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ms: mean,
        min_ms: times.iter().copied().fold(f64::MAX, f64::min),
        max_ms: times.iter().copied().fold(0.0, f64::max),
    };
    println!(
        "{:<44} {:>4} iters  mean {:>10.3} ms  min {:>10.3}  max {:>10.3}",
        r.name, r.iters, r.mean_ms, r.min_ms, r.max_ms
    );
    r
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

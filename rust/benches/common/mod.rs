//! Minimal bench harness (criterion is not vendored offline): warmup +
//! timed iterations with mean/min/max reporting, plus a machine-readable
//! JSON sink so the perf trajectory is tracked PR over PR
//! (`BENCH_<suite>.json`, overridable with `--json <path>`).
#![allow(dead_code)] // each bench bin compiles its own copy; not all use every helper

use flip::report::Json;
use std::path::PathBuf;
use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: u32,
    pub mean_ms: f64,
    pub min_ms: f64,
    pub max_ms: f64,
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench<F: FnMut()>(name: &str, warmup: u32, iters: u32, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters as usize);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ms: mean,
        min_ms: times.iter().copied().fold(f64::MAX, f64::min),
        max_ms: times.iter().copied().fold(0.0, f64::max),
    };
    println!(
        "{:<44} {:>4} iters  mean {:>10.3} ms  min {:>10.3}  max {:>10.3}",
        r.name, r.iters, r.mean_ms, r.min_ms, r.max_ms
    );
    r
}

/// Print a section header.
pub fn section(title: &str) {
    println!("\n=== {title} ===");
}

/// Machine-readable result collector for one bench binary. Push every
/// [`BenchResult`] (plus any derived metrics such as simulated
/// PE-cycles/s) and write a JSON file at the end.
pub struct Suite {
    name: String,
    entries: Vec<(BenchResult, Vec<(String, f64)>)>,
}

impl Suite {
    pub fn new(name: &str) -> Suite {
        Suite { name: name.to_string(), entries: Vec::new() }
    }

    /// Record a bench result (returns `&mut self` for chaining).
    pub fn add(&mut self, r: BenchResult) -> &mut Suite {
        self.entries.push((r, Vec::new()));
        self
    }

    /// Attach a derived metric to the most recently added result.
    pub fn metric(&mut self, key: &str, value: f64) -> &mut Suite {
        if let Some((_, extras)) = self.entries.last_mut() {
            extras.push((key.to_string(), value));
        }
        self
    }

    /// Default output path: `BENCH_<suite>.json` in the crate root,
    /// overridable with `--json <path>` on the bench command line
    /// (`cargo bench --bench bench_flip_sim -- --json out.json`).
    pub fn out_path(&self) -> PathBuf {
        json_arg().unwrap_or_else(|| PathBuf::from(format!("BENCH_{}.json", self.name)))
    }

    /// Serialize all recorded results (no serde offline — uses the
    /// crate's minimal JSON writer).
    pub fn to_json(&self) -> Json {
        let results: Vec<Json> = self
            .entries
            .iter()
            .map(|(r, extras)| {
                let mut obj = vec![
                    ("name".to_string(), Json::Str(r.name.clone())),
                    ("iters".to_string(), Json::Num(r.iters as f64)),
                    ("mean_ms".to_string(), Json::Num(r.mean_ms)),
                    ("min_ms".to_string(), Json::Num(r.min_ms)),
                    ("max_ms".to_string(), Json::Num(r.max_ms)),
                ];
                for (k, v) in extras {
                    obj.push((k.clone(), Json::Num(*v)));
                }
                Json::Obj(obj)
            })
            .collect();
        let unix = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs() as f64)
            .unwrap_or(0.0);
        Json::Obj(vec![
            ("suite".to_string(), Json::Str(self.name.clone())),
            ("created_unix".to_string(), Json::Num(unix)),
            ("results".to_string(), Json::Arr(results)),
        ])
    }

    /// Write the JSON file and report where it went.
    pub fn write(&self) -> std::io::Result<PathBuf> {
        let path = self.out_path();
        std::fs::write(&path, self.to_json().render() + "\n")?;
        println!("\n[bench json written to {}]", path.display());
        Ok(path)
    }
}

/// Parse `--json <path>` from the bench binary's argument list.
pub fn json_arg() -> Option<PathBuf> {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .map(PathBuf::from)
}

//! Bench: FLIP compiler phases (Fig 13 timing source) and ablations
//! (beam-only vs +local-opt vs layout-sort-off).

mod common;

use flip::compiler::{compile, CompileOpts};
use flip::config::ArchConfig;
use flip::graph::datasets::{self, Group};

fn main() {
    let cfg = ArchConfig::default();
    common::section("FLIP compiler per dataset group (Fig 13b)");
    for group in Group::ON_CHIP {
        let g = datasets::generate_one(group, 0, 42);
        common::bench(
            &format!("{} (|V|={} |E|={})", group.name(), g.num_vertices(), g.num_edges()),
            1,
            5,
            || {
                compile(&g, &cfg, &CompileOpts::default());
            },
        );
    }

    common::section("Ablations (LRN)");
    let g = datasets::generate_one(Group::Lrn, 0, 42);
    let full = compile(&g, &cfg, &CompileOpts::default());
    let beam_only =
        compile(&g, &cfg, &CompileOpts { skip_local_opt: true, ..Default::default() });
    common::bench("beam search only", 1, 5, || {
        compile(&g, &cfg, &CompileOpts { skip_local_opt: true, ..Default::default() });
    });
    common::bench("beam + local optimization", 1, 5, || {
        compile(&g, &cfg, &CompileOpts::default());
    });
    common::bench("no farthest-first layout sort", 1, 5, || {
        compile(&g, &cfg, &CompileOpts { skip_layout_sort: true, ..Default::default() });
    });
    println!(
        "    -> routing length: beam-only {:.3} vs optimized {:.3}; congested arcs {} vs {}",
        beam_only.stats.avg_routing_length,
        full.stats.avg_routing_length,
        beam_only.stats.congested_edges,
        full.stats.congested_edges
    );

    common::section("Scaling (road networks)");
    for (n, lo, hi) in [(64usize, 146usize, 166usize), (128, 292, 330), (256, 584, 650)] {
        let g = flip::graph::generate::road_network(n, lo, hi, 7);
        common::bench(&format!("|V|={n}"), 1, 3, || {
            compile(&g, &cfg, &CompileOpts::default());
        });
    }
}

"""L1 correctness: Pallas relax kernel vs pure-jnp oracle.

Hypothesis sweeps graph sizes, tile choices, weight ranges and inf
patterns; every case asserts exact f32 agreement with `ref.relax_step_ref`
(the kernel performs the same adds/mins, so results are bit-identical).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, relax

INF = np.float32(np.inf)


def random_case(rng: np.random.Generator, n: int, density: float):
    w = rng.uniform(0.5, 10.0, size=(n, n)).astype(np.float32)
    mask = rng.uniform(size=(n, n)) > density
    w[mask] = INF
    np.fill_diagonal(w, INF)
    d = rng.uniform(0.0, 20.0, size=n).astype(np.float32)
    d[rng.uniform(size=n) > 0.5] = INF
    if np.all(np.isinf(d)):
        d[0] = 0.0
    return d, w


@pytest.mark.parametrize("n", [1, 2, 4, 8, 16, 64])
def test_matches_ref_exact(n):
    rng = np.random.default_rng(n)
    d, w = random_case(rng, n, 0.3)
    got = np.asarray(relax.relax_step(d, w))
    want = np.asarray(ref.relax_step_ref(d, w))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=40, deadline=None)
@given(
    n=st.sampled_from([2, 3, 4, 6, 8, 12, 16, 24, 32]),
    seed=st.integers(0, 2**31 - 1),
    density=st.floats(0.05, 0.9),
    tile=st.sampled_from([None, 1, 2, 4, 8, 64]),
)
def test_matches_ref_hypothesis(n, seed, density, tile):
    rng = np.random.default_rng(seed)
    d, w = random_case(rng, n, density)
    got = np.asarray(relax.relax_step(d, w, tile=tile))
    want = np.asarray(ref.relax_step_ref(d, w))
    np.testing.assert_array_equal(got, want)


@settings(max_examples=20, deadline=None)
@given(n=st.sampled_from([4, 8, 16]), seed=st.integers(0, 2**31 - 1))
def test_monotone_nonincreasing(n, seed):
    """Relaxation never increases any attribute (simulator invariant too)."""
    rng = np.random.default_rng(seed)
    d, w = random_case(rng, n, 0.4)
    out = np.asarray(relax.relax_step(d, w))
    assert np.all((out <= d) | (np.isinf(out) & np.isinf(d)))


def test_all_inf_edges_is_identity():
    n = 8
    d = np.arange(n, dtype=np.float32)
    w = np.full((n, n), INF, dtype=np.float32)
    out = np.asarray(relax.relax_step(d, w))
    np.testing.assert_array_equal(out, d)


def test_fixpoint_is_idempotent():
    rng = np.random.default_rng(7)
    d, w = random_case(rng, 16, 0.3)
    fp = ref.relax_fixpoint_ref(d, w)
    out = np.asarray(relax.relax_step(fp, w))
    np.testing.assert_array_equal(out, fp)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), k=st.integers(1, 5))
def test_relax_k_equals_iterated_step(seed, k):
    rng = np.random.default_rng(seed)
    d, w = random_case(rng, 8, 0.4)
    got = np.asarray(relax.relax_k(d, w, k))
    want = np.asarray(ref.relax_k_ref(d, w, k))
    np.testing.assert_array_equal(got, want)


def test_changed_count():
    a = np.array([0.0, 1.0, INF, 3.0], dtype=np.float32)
    b = np.array([0.0, 0.5, INF, 2.0], dtype=np.float32)
    assert int(relax.changed_count(a, b)) == 2
    assert int(relax.changed_count(a, a)) == 0

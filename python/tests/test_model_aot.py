"""L2 shape/lowering checks and AOT artifact validity."""

import json
import os

import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def test_entry_points_execute():
    n = 16
    rng = np.random.default_rng(0)
    d = rng.uniform(0, 5, n).astype(np.float32)
    w = rng.uniform(0.5, 3, (n, n)).astype(np.float32)
    (out,) = model.relax_step_fn(d, w)
    assert out.shape == (n,)
    (out_k,) = model.relax_k_fn(d, w)
    want = ref.relax_k_ref(d, w, model.SCAN_K)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(want))
    d2, changed = model.relax_step_count_fn(d, w)
    assert d2.shape == (n,)
    assert int(changed) == int(np.sum(np.asarray(out) != d))


@pytest.mark.parametrize("name", list(model.ENTRY_POINTS))
def test_lowering_produces_hlo_text(name, tmp_path):
    text = aot.to_hlo_text(model.lower(name, 16))
    assert "HloModule" in text
    assert "f32[16,16]" in text


def test_export_all_manifest(tmp_path):
    # Patch sizes down so the test is fast.
    orig = aot.EXPORTS
    aot.EXPORTS = [("relax_step", (16,)), ("relax_step_count", (16,))]
    try:
        manifest = aot.export_all(str(tmp_path))
    finally:
        aot.EXPORTS = orig
    files = os.listdir(tmp_path)
    assert "relax_step_n16.hlo.txt" in files
    assert "manifest.json" in files
    with open(tmp_path / "manifest.json") as f:
        m = json.load(f)
    assert m == manifest
    mods = {x["name"]: x for x in m["modules"]}
    assert mods["relax_step"]["outputs"] == 1
    assert mods["relax_step_count"]["outputs"] == 2
    with open(tmp_path / "relax_step_n16.hlo.txt") as f:
        assert f.read().startswith("HloModule")

"""Oracle self-checks: dense relaxation vs. textbook Dijkstra/BFS/union-find.

The rust simulator is validated against the AOT artifacts, and the
artifacts against `ref.py` — so `ref.py` itself must be beyond doubt.
"""

import heapq

import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def dijkstra(n, adj, source):
    dist = [float("inf")] * n
    dist[source] = 0.0
    pq = [(0.0, source)]
    while pq:
        d, u = heapq.heappop(pq)
        if d > dist[u]:
            continue
        for v, w in adj[u]:
            nd = d + w
            if nd < dist[v]:
                dist[v] = nd
                heapq.heappush(pq, (nd, v))
    return np.array(dist, dtype=np.float32)


def random_graph(rng, n, m):
    edges, weights = [], []
    for _ in range(m):
        u, v = rng.integers(0, n, size=2)
        if u != v:
            edges.append((int(u), int(v)))
            weights.append(float(rng.integers(1, 10)))
    return edges, weights


@settings(max_examples=30, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 24))
def test_sssp_ref_matches_dijkstra(seed, n):
    rng = np.random.default_rng(seed)
    edges, weights = random_graph(rng, n, 3 * n)
    source = int(rng.integers(0, n))
    got = ref.sssp_ref(n, edges, weights, source, undirected=True)
    adj = [[] for _ in range(n)]
    for (u, v), w in zip(edges, weights):
        adj[u].append((v, w))
        adj[v].append((u, w))
    want = dijkstra(n, adj, source)
    np.testing.assert_allclose(got, want)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 24))
def test_bfs_ref_matches_queue_bfs(seed, n):
    rng = np.random.default_rng(seed)
    edges, _ = random_graph(rng, n, 2 * n)
    source = int(rng.integers(0, n))
    got = ref.bfs_levels_ref(n, edges, source, undirected=True)
    # plain queue BFS
    from collections import deque

    adj = [[] for _ in range(n)]
    for u, v in edges:
        adj[u].append(v)
        adj[v].append(u)
    lvl = [float("inf")] * n
    lvl[source] = 0
    q = deque([source])
    while q:
        u = q.popleft()
        for v in adj[u]:
            if lvl[v] == float("inf"):
                lvl[v] = lvl[u] + 1
                q.append(v)
    np.testing.assert_array_equal(got, np.array(lvl, dtype=np.float32))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.integers(2, 24))
def test_wcc_ref_matches_union_find(seed, n):
    rng = np.random.default_rng(seed)
    edges, _ = random_graph(rng, n, n)
    got = ref.wcc_labels_ref(n, edges)
    parent = list(range(n))

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    for u, v in edges:
        parent[find(u)] = find(v)
    # canonical label = min vertex id in component
    comp_min = {}
    for v in range(n):
        r = find(v)
        comp_min[r] = min(comp_min.get(r, v), v)
    want = np.array([comp_min[find(v)] for v in range(n)], dtype=np.float32)
    np.testing.assert_array_equal(got, want)


def test_adjacency_parallel_edges_keep_min():
    w = ref.adjacency_from_edges(3, [(0, 1), (0, 1)], [5.0, 2.0])
    assert w[0, 1] == 2.0

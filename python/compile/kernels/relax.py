"""L1 Pallas kernel: tiled dense min-plus relaxation step.

This is the compute hot-spot of the FLIP golden model.  One step computes

    d'[v] = min(d[v], min_u (d[u] + W[u, v]))

over a dense f32 adjacency W (inf = no edge).

TPU mapping (see DESIGN.md §Hardware-Adaptation): the paper's fabric is a
22nm CGRA, so there is no CUDA kernel to port — instead the *algorithmic
core* (frontier relaxation) is tiled for VMEM.  The grid is
(n/TILE_V destination tiles, n/TILE_U source tiles) with the source axis
innermost, so each output block stays resident while all source tiles are
reduced into it (the Pallas analogue of per-PE accumulation in FLIP).
min/add run on the VPU — the op is memory-bound (one f32 load per W entry,
O(1) flops each), so the roofline target is HBM bandwidth, not the MXU.

Must be lowered with ``interpret=True``: real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Default tile edge. 256-vertex graphs (the 8x8 FLIP array at 4 vertices/PE)
#: tile as 4x4 blocks of 64; smaller graphs use a single tile.
DEFAULT_TILE = 64


def _pick_tile(n: int, tile: int | None) -> int:
    t = min(tile or DEFAULT_TILE, n)
    while n % t != 0:  # shapes are padded to powers of two upstream
        t -= 1
    return max(t, 1)


def _relax_kernel(d_src_ref, d_dst_ref, w_ref, o_ref):
    """Grid cell (i, j): fold source tile j into destination tile i.

    o[i] is initialised from d on the first source tile and revisited
    (same output block) for every j — accumulation across the inner grid
    axis, as the Pallas revisiting-output-block idiom.
    """
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = d_dst_ref[...]

    # min over the source axis of (d[u] + W[u, v]) for this tile pair.
    cand = jnp.min(d_src_ref[...][:, None] + w_ref[...], axis=0)
    o_ref[...] = jnp.minimum(o_ref[...], cand)


def relax_step(d: jnp.ndarray, w: jnp.ndarray, *, tile: int | None = None) -> jnp.ndarray:
    """One min-plus relaxation step as a Pallas call.

    d: f32[n], w: f32[n, n]  ->  f32[n]
    """
    n = d.shape[0]
    assert w.shape == (n, n), f"adjacency must be square, got {w.shape}"
    t = _pick_tile(n, tile)
    grid = (n // t, n // t)
    return pl.pallas_call(
        _relax_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((t,), lambda i, j: (j,)),   # d as source tile
            pl.BlockSpec((t,), lambda i, j: (i,)),   # d as dest-init tile
            pl.BlockSpec((t, t), lambda i, j: (j, i)),  # W tile (src, dst)
        ],
        out_specs=pl.BlockSpec((t,), lambda i, j: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(d, d, w)


def relax_k(d: jnp.ndarray, w: jnp.ndarray, k: int, *, tile: int | None = None) -> jnp.ndarray:
    """k relaxation steps under lax.scan (amortizes PJRT dispatch in rust)."""
    step = functools.partial(relax_step, tile=tile)

    def body(carry, _):
        return step(carry, w), None

    out, _ = jax.lax.scan(body, d, None, length=k)
    return out


def changed_count(d_old: jnp.ndarray, d_new: jnp.ndarray) -> jnp.ndarray:
    """Number of vertices whose attribute changed (fixpoint detection)."""
    return jnp.sum((d_old != d_new).astype(jnp.int32))

"""Pure-jnp oracle for the FLIP golden-model compute.

The FLIP fabric executes graph workloads as distributed, asynchronous
min-plus relaxation over the vertex set.  The dense golden model expresses
one *synchronous* relaxation step:

    d'[v] = min(d[v], min_u (d[u] + W[u, v]))

with ``W[u, v] = +inf`` when there is no edge ``u -> v``.  Iterating to
fixpoint yields:

  * **SSSP** distances (W = edge weights, d0 = 0 at source, inf elsewhere)
  * **BFS** levels      (W = 1 on edges)
  * **WCC** labels      (W = 0 on edges, d0 = vertex index) — min-label
    propagation over the undirected edge set.

These functions are the correctness oracle for the Pallas kernel
(`relax.py`) and, transitively, for the Rust cycle-accurate simulator
(which must agree with the AOT-compiled HLO built from the kernel).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

INF = np.float32(np.inf)


def relax_step_ref(d: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """One synchronous min-plus relaxation step (the oracle).

    d: f32[n]    current tentative attributes (inf = unreached)
    w: f32[n, n] dense adjacency, w[u, v] = weight of edge u->v, inf = no edge
    """
    cand = jnp.min(d[:, None] + w, axis=0)
    return jnp.minimum(d, cand)


def relax_k_ref(d: jnp.ndarray, w: jnp.ndarray, k: int) -> jnp.ndarray:
    """k synchronous relaxation steps (oracle for the lax.scan variant)."""
    for _ in range(k):
        d = relax_step_ref(d, w)
    return d


def relax_fixpoint_ref(d: np.ndarray, w: np.ndarray, max_iter: int | None = None) -> np.ndarray:
    """Iterate relax_step_ref to fixpoint (numpy, exact convergence check)."""
    d = np.asarray(d, dtype=np.float32)
    w = np.asarray(w, dtype=np.float32)
    n = d.shape[0]
    limit = max_iter if max_iter is not None else n + 1
    for _ in range(limit):
        nxt = np.minimum(d, np.min(d[:, None] + w, axis=0))
        if np.array_equal(nxt, d, equal_nan=True):
            return nxt
        d = nxt
    return d


def adjacency_from_edges(n: int, edges, weights=None, undirected: bool = False) -> np.ndarray:
    """Build the dense f32 adjacency with +inf non-edges.

    edges: iterable of (u, v); weights: per-edge f32 (default 1.0).
    Parallel edges keep the minimum weight (matches CSR semantics in rust).
    """
    w = np.full((n, n), INF, dtype=np.float32)
    for i, (u, v) in enumerate(edges):
        wt = np.float32(1.0) if weights is None else np.float32(weights[i])
        w[u, v] = min(w[u, v], wt)
        if undirected:
            w[v, u] = min(w[v, u], wt)
    return w


def sssp_ref(n: int, edges, weights, source: int, undirected: bool = True) -> np.ndarray:
    """SSSP distances via dense relaxation (Bellman-Ford fixpoint)."""
    w = adjacency_from_edges(n, edges, weights, undirected)
    d = np.full(n, INF, dtype=np.float32)
    d[source] = 0.0
    return relax_fixpoint_ref(d, w)


def bfs_levels_ref(n: int, edges, source: int, undirected: bool = True) -> np.ndarray:
    """BFS levels = SSSP with unit weights."""
    w = adjacency_from_edges(n, edges, None, undirected)
    d = np.full(n, INF, dtype=np.float32)
    d[source] = 0.0
    return relax_fixpoint_ref(d, w)


def wcc_labels_ref(n: int, edges) -> np.ndarray:
    """WCC labels via min-label propagation (zero-weight, undirected)."""
    edges = list(edges)
    w = adjacency_from_edges(n, edges, [0.0] * len(edges), undirected=True)
    d = np.arange(n, dtype=np.float32)
    return relax_fixpoint_ref(d, w)

"""AOT export: lower the L2 model to HLO **text** artifacts.

HLO text (NOT `.serialize()`) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids, which xla_extension 0.5.1 (the
version pinned by the published `xla` 0.1.6 crate) rejects
(`proto.id() <= INT_MAX`).  The text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts
"""

from __future__ import annotations

import argparse
import json
import os

from jax._src.lib import xla_client as xc

from . import model

#: Dense sizes to export. 16/64 cover unit tests and SRN graphs, 256 is the
#: paper's 8x8 array at 4 vertices/PE, 1024 covers the Fig-12 16x16 scaling
#: point. Ext.LRN (16k) is validated against the native rust reference
#: instead (a 16k^2 dense matrix is out of scope for the golden model).
SIZES = (16, 64, 256, 1024)

#: (entry point, sizes) pairs to export.
EXPORTS = [
    ("relax_step", SIZES),
    ("relax_k8", (16, 64, 256)),
    ("relax_step_count", (16, 64, 256)),
]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "return_tuple": True, "modules": []}
    for name, sizes in EXPORTS:
        for n in sizes:
            text = to_hlo_text(model.lower(name, n))
            fname = f"{name}_n{n}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            manifest["modules"].append(
                {
                    "name": name,
                    "n": n,
                    "file": fname,
                    "inputs": [f"f32[{n}]", f"f32[{n},{n}]"],
                    "outputs": 2 if name == "relax_step_count" else 1,
                    "scan_k": model.SCAN_K if name == "relax_k8" else None,
                }
            )
            print(f"wrote {fname} ({len(text)} chars)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    export_all(args.out_dir)


if __name__ == "__main__":
    main()

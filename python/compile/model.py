"""L2 JAX model: the FLIP golden-model compute graph.

Composes the L1 Pallas kernel (`kernels.relax`) into the exported entry
points.  Each entry point is a pure function of dense arrays — lowered once
by `aot.py` to HLO text and executed from the Rust runtime
(`rust/src/runtime`) via PJRT.  Python never runs on the request path.

Exported programs (all return 1-tuples, unwrapped with `to_tuple1` in rust):

  relax_step(d, w)            -> (d',)              one synchronous step
  relax_k(d, w)               -> (d',)              K steps via lax.scan
  relax_step_count(d, w)      -> (d', changed)      step + fixpoint counter
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import relax

#: Step count for the scanned variant; 8 amortizes PJRT dispatch while
#: keeping the artifact small (road-network diameters are ~tens of steps).
SCAN_K = 8


def relax_step_fn(d, w):
    return (relax.relax_step(d, w),)


def relax_k_fn(d, w):
    return (relax.relax_k(d, w, SCAN_K),)


def relax_step_count_fn(d, w):
    d2 = relax.relax_step(d, w)
    return (d2, relax.changed_count(d, d2))


ENTRY_POINTS = {
    "relax_step": relax_step_fn,
    "relax_k8": relax_k_fn,
    "relax_step_count": relax_step_count_fn,
}


def lower(name: str, n: int):
    """Lower entry point `name` for an n-vertex dense graph; returns Lowered."""
    fn = ENTRY_POINTS[name]
    d_spec = jax.ShapeDtypeStruct((n,), jnp.float32)
    w_spec = jax.ShapeDtypeStruct((n, n), jnp.float32)
    return jax.jit(fn).lower(d_spec, w_spec)

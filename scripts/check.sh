#!/usr/bin/env bash
# Repo gate: format, lint, release build, docs, examples, tests. Run from
# anywhere. The default build is dependency-free (no network needed); the
# PJRT golden tests skip visibly unless artifacts + the `pjrt` feature
# exist.
set -euo pipefail

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found on PATH." >&2
    echo "       Install a Rust toolchain (https://rustup.rs) and re-run; the gate" >&2
    echo "       needs rustfmt + clippy components (rustup component add rustfmt clippy)." >&2
    exit 1
fi

cd "$(dirname "$0")/../rust"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy -- -D warnings =="
cargo clippy --all-targets -- -D warnings

# Production code (lib + bins) must not panic through unwrap/expect —
# typed SimError/QueryError paths exist for every failure (DESIGN.md §8).
# Scoping to --lib --bins keeps the ban out of #[cfg(test)] modules,
# tests/ and benches/, where unwrap-on-known-good is the right idiom.
echo "== cargo clippy --lib --bins (deny unwrap/expect) =="
cargo clippy --lib --bins -- -D warnings -D clippy::unwrap_used -D clippy::expect_used

echo "== cargo build --release =="
cargo build --release

echo "== cargo doc --no-deps (warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "== cargo build --examples =="
cargo build --examples

# Compile-check the bench binaries without running them: cheap, and it
# catches bench rot (stale APIs in benches/) that clippy's --all-targets
# lint pass would only flag, not link.
echo "== cargo bench --no-run =="
cargo bench --no-run

# Wall-clock cap on the test step: a hung lockstep/simulator loop must
# fail the gate fast instead of eating the whole CI budget. Override with
# TEST_TIMEOUT_SECS; falls back to an uncapped run where coreutils
# `timeout` is unavailable.
TEST_TIMEOUT_SECS="${TEST_TIMEOUT_SECS:-1500}"
echo "== cargo test -q (wall-clock cap ${TEST_TIMEOUT_SECS}s) =="
if command -v timeout >/dev/null 2>&1; then
    timeout -k 30 "${TEST_TIMEOUT_SECS}" cargo test -q
else
    echo "warning: coreutils 'timeout' not found; running tests uncapped" >&2
    cargo test -q
fi

# Short sustained-load smoke of the streaming server (DESIGN.md §9):
# 5 seconds of open-loop admission with weight updates racing queries.
# The JSON lands as BENCH_serve_smoke.json so CI's bench-artifact glob
# uploads it, and the gate asserts on it instead of scraping text:
# zero failed queries, zero deadline aborts (no deadline configured),
# and a recorded p99 modeled-cycle latency.
echo "== flip serve --duration smoke (streaming SLO) =="
./target/release/flip serve --group srn --duration 5 --qps-target 40 \
    --update-rate 4 --threads 2 --json BENCH_serve_smoke.json
grep -q '"failed":0,' BENCH_serve_smoke.json \
    || { echo "error: streaming smoke reported failed queries" >&2; exit 1; }
grep -q '"deadline_aborts":0' BENCH_serve_smoke.json \
    || { echo "error: streaming smoke reported deadline aborts" >&2; exit 1; }
grep -q '"p99_cycles":' BENCH_serve_smoke.json \
    || { echo "error: streaming smoke JSON is missing p99_cycles" >&2; exit 1; }

# The overload ladder's counters must be present (zero here: no deadline
# and no chaos configured, so the resilience layer is provably inert in
# the smoke) — a missing field means the telemetry contract regressed.
grep -q '"shed":' BENCH_serve_smoke.json \
    || { echo "error: streaming smoke JSON is missing shed" >&2; exit 1; }
grep -q '"degraded":' BENCH_serve_smoke.json \
    || { echo "error: streaming smoke JSON is missing degraded" >&2; exit 1; }

# Batched-drain accounting (DESIGN.md §Perf.2): every completed query is
# either a lane of a (possibly fused) sim pass or a frontier-sharing
# fan-out, never both and never neither:
#   served + failed == shared_hits + lane_count
smoke_num() {
    grep -o "\"$1\":[0-9]*" "${2:-BENCH_serve_smoke.json}" | head -1 | cut -d: -f2
}
served="$(smoke_num served)"; failed="$(smoke_num failed)"
hits="$(smoke_num shared_hits)"; lanes="$(smoke_num lane_count)"
if [ -z "$served" ] || [ -z "$failed" ] || [ -z "$hits" ] || [ -z "$lanes" ]; then
    echo "error: streaming smoke JSON is missing lane accounting fields" >&2
    exit 1
fi
if [ "$((served + failed))" -ne "$((hits + lanes))" ]; then
    echo "error: lane conservation violated: served($served) + failed($failed)" >&2
    echo "       != shared_hits($hits) + lane_count($lanes)" >&2
    exit 1
fi

# Overload drill (DESIGN.md §11): the same serving scenario pushed to
# ~3x the smoke's measured capacity for 5 seconds, with a deadline
# budget (arming the shedding ladder) and a fixed chaos seed (seeded
# worker slowdowns, drain stalls, epoch-build refusals, worker panics,
# synthetic fatals). The gate asserts the ticket conservation ledger —
# submitted == served + failed + shed + rejected — and that the run
# neither hangs (wall cap) nor crashes; individual injected failures are
# the point, not a regression. The chaos seed is pinned so any failure
# here reproduces with `flip serve --chaos 3405691582 ...`.
echo "== flip serve --chaos overload drill (degradation ladder) =="
cap_qps="$(grep -o '"stream_qps":[0-9]*' BENCH_serve_smoke.json | head -1 | cut -d: -f2)"
overload_qps="$(awk -v c="${cap_qps:-40}" 'BEGIN { q = int(3 * c); print (q > 120) ? q : 120 }')"
overload_cmd=(./target/release/flip serve --group srn --duration 5 \
    --qps-target "$overload_qps" --update-rate 4 --threads 2 \
    --deadline 2000000 --chaos 3405691582 --json BENCH_serve_overload.json)
if command -v timeout >/dev/null 2>&1; then
    timeout -k 30 120 "${overload_cmd[@]}"
else
    "${overload_cmd[@]}"
fi
o_submitted="$(smoke_num submitted BENCH_serve_overload.json)"
o_served="$(smoke_num served BENCH_serve_overload.json)"
o_failed="$(smoke_num failed BENCH_serve_overload.json)"
o_shed="$(smoke_num shed BENCH_serve_overload.json)"
o_rejected="$(smoke_num rejected BENCH_serve_overload.json)"
if [ -z "$o_submitted" ] || [ -z "$o_served" ] || [ -z "$o_failed" ] \
    || [ -z "$o_shed" ] || [ -z "$o_rejected" ]; then
    echo "error: overload drill JSON is missing ledger fields" >&2
    exit 1
fi
if [ "$o_submitted" -ne "$((o_served + o_failed + o_shed + o_rejected))" ]; then
    echo "error: overload ticket ledger leaked: submitted($o_submitted)" >&2
    echo "       != served($o_served) + failed($o_failed) + shed($o_shed)" >&2
    echo "       + rejected($o_rejected)" >&2
    exit 1
fi
grep -q '"chaos_panics":' BENCH_serve_overload.json \
    || { echo "error: overload drill JSON is missing chaos_panics" >&2; exit 1; }

# Beam-search ANN smoke (DESIGN.md §10): one seeded query batch over a
# clustered 256-vertex index, asserted on the JSON sink. The fabric is
# bitwise the CPU oracle, so the recall@10 >= 0.9 gate is a pure
# index/algorithm check — a fabric regression fails the test suite
# above, a seeding/index regression fails here.
echo "== flip run --workload ann smoke (recall gate) =="
./target/release/flip run --workload ann --n 256 --queries 16 --beam 48 \
    --json BENCH_ann_smoke.json
recall="$(grep -o '"ann_recall_at_10":[0-9.]*' BENCH_ann_smoke.json | head -1 | cut -d: -f2)"
if [ -z "$recall" ]; then
    echo "error: ANN smoke JSON is missing ann_recall_at_10" >&2
    exit 1
fi
if ! awk -v r="$recall" 'BEGIN { exit !(r >= 0.9) }'; then
    echo "error: ANN smoke recall@10 $recall < 0.9" >&2
    exit 1
fi
grep -q '"ann_qps":' BENCH_ann_smoke.json \
    || { echo "error: ANN smoke JSON is missing ann_qps" >&2; exit 1; }

echo "all checks passed"

#!/usr/bin/env bash
# Repo gate: format, lint, release build, docs, examples, tests. Run from
# anywhere. The default build is dependency-free (no network needed); the
# PJRT golden tests skip visibly unless artifacts + the `pjrt` feature
# exist.
set -euo pipefail

if ! command -v cargo >/dev/null 2>&1; then
    echo "error: cargo not found on PATH." >&2
    echo "       Install a Rust toolchain (https://rustup.rs) and re-run; the gate" >&2
    echo "       needs rustfmt + clippy components (rustup component add rustfmt clippy)." >&2
    exit 1
fi

cd "$(dirname "$0")/../rust"

echo "== cargo fmt --check =="
cargo fmt --check

echo "== cargo clippy -- -D warnings =="
cargo clippy --all-targets -- -D warnings

echo "== cargo build --release =="
cargo build --release

echo "== cargo doc --no-deps (warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps

echo "== cargo build --examples =="
cargo build --examples

echo "== cargo test -q =="
cargo test -q

echo "all checks passed"
